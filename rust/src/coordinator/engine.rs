//! The coordination engine: the transactional core behind the REST APIs.
//!
//! ## Sharded layout
//!
//! The seed engine funneled every study, trial and WAL append through a
//! single global `Mutex<Inner>`, so multi-study, multi-site campaigns —
//! the whole point of the paper's "scalable set of Uvicorn instances" —
//! contended on one lock and one fsync. The engine is now three layers:
//!
//! * **registry** (`registry.rs`): a `RwLock` study directory for the
//!   cross-study read APIs plus a lock-striped `trial_id → shard`
//!   router. Placement is stable: `shard = fnv1a(study_key) % N`.
//! * **shards**: N independent [`Shard`]s, each owning its studies'
//!   trials, sampler history and `last_seen` reaping state under its own
//!   lock. Asks/tells on different studies never contend.
//! * **group-commit WAL** (`store::GroupWal`): mutations from all shards
//!   are appended in arrival order by one writer thread, fsynced once
//!   per batch, and only then acknowledged — "acknowledged ⇒ durable"
//!   is preserved (E7 tests it) while N concurrent fsyncs collapse into
//!   one.
//!
//! Lock ordering: the canonical hierarchy is declared once, in
//! [`crate::analysis::HIERARCHY`], and enforced by `hopaas-lint`.
//! Ascending acquisition order: serializers (compaction,
//! follower-apply) → registry directory → fleet bind gate → shard →
//! fleet → view slots/builders/leaves → WAL writer queue → WAL ledger
//! → replication ring → router stripes → metrics/obs. Readers copy out
//! of the directory before taking a shard lock; directory *writers*
//! publish entries only after the owning shard guard is released (see
//! [`Engine::publish_dir_entry`]); no path ever holds two shard locks —
//! including compaction, which cuts one per-shard snapshot segment at a
//! time, pausing only the shard being cut (see [`Engine::compact`]).
//!
//! Recovery is parallel: the log is partitioned by *study* (stable
//! `place(study_key, P)` buckets, so a study's records stay together
//! whatever shard count wrote them) and each partition replays on its
//! own thread — see [`Engine::open_with_storage`].
//!
//! Determinism: sampler draws are seeded from
//! `mix(mix(seed, fnv1a(study_key)), trial_number)` — a pure function of
//! the study definition, untouched by sharding — and the trial number is
//! *reserved under the shard lock before sampling*, so concurrent asks
//! (even on the same study) draw distinct numbers. Recovery replay, a
//! second server instance, or the same campaign on a different shard
//! count produces the same suggestion stream (the property PostgreSQL
//! gives the paper's backends).

use super::registry::{fnv1a, place, DirEntry, Directory, TrialRouter};
use super::samplers::{is_known_sampler, make_sampler, FitState, Obs, Sampler};
use super::space::{assignment_to_json, Assignment};
use super::study::{parse_ask_body, Study, StudyDef};
use super::trial::{Trial, TrialState};
use super::views::{EventKind, ViewRegistry};
use super::{metrics::Metrics, pruners::make_pruner};
use crate::fleet::{Fleet, FleetConfig};
use crate::json::Value;
use crate::obs::{self, Stage, Tracer, TracerConfig};
use crate::rng::{mix, Rng};
use crate::store::{
    GroupWal, GroupWalConfig, LoadedState, Record, RecoveryStats, ReplicationSource, Storage,
    WalAckInfo, FLEET_SHARD,
};
use crate::sync::{MutexExt, RwLockExt};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};
use std::time::Instant;

/// API-level error → HTTP status mapping happens in the service layer.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ApiError {
    #[error("{0}")]
    BadRequest(String),
    #[error("{0}")]
    NotFound(String),
    #[error("{0}")]
    Conflict(String),
    /// Site/study concurrency quota denial (HTTP 429: back off, retry).
    #[error("{0}")]
    Quota(String),
    /// Write rejected: this node is a read-only follower (HTTP 503).
    /// Carries the primary's URL when configured so clients can
    /// redirect without operator action.
    #[error("read-only follower")]
    ReadOnly(Option<String>),
    #[error("storage failure: {0}")]
    Storage(String),
}

/// Engine tuning.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Base seed for the deterministic sampler streams.
    pub seed: u64,
    /// Compact the WAL into a snapshot after this many records.
    pub compact_after: u64,
    /// Side threads cutting snapshot segments during a compaction.
    /// 0 (the default) means `min(n_shards, cores)`; 1 reproduces the
    /// sequential one-segment-at-a-time layout byte for byte. Whatever
    /// the pool size, the manifest commit stays serialized through the
    /// WAL writer thread.
    pub compact_threads: usize,
    /// Mark a running trial failed if silent for this many seconds
    /// (opportunistic nodes vanish without a goodbye). `None` disables.
    pub reap_after: Option<f64>,
    /// §Perf: clone at most this many (most recent) scored observations
    /// into the per-ask sampler snapshot. Every model-based sampler
    /// windows its history anyway (TPE 1024, GP 256, CMA-ES λ·gens), so
    /// cloning the full multi-thousand-trial history per ask is pure
    /// waste.
    pub history_snapshot: usize,
    /// Number of engine shards. Studies hash-place onto shards, so
    /// mutations on different studies contend only within a shard.
    /// 1 reproduces the seed's single-lock behavior exactly.
    pub n_shards: usize,
    /// Largest number of WAL records flushed under one fsync by the
    /// group-commit writer.
    pub wal_batch_max: usize,
    /// Replay partitions (= threads) used for parallel recovery.
    /// 0 (the default) means "one per shard". Partitioning is by study
    /// key, so any value is correct; more partitions than CPU cores
    /// just wastes spawns.
    pub replay_threads: usize,
    /// Adapt the group-commit batch limit to the observed queue depth
    /// (grow under bursts up to `wal_batch_max`, decay when idle).
    /// `--wal-batch N` turns this off and fixes the limit at N.
    pub wal_batch_adaptive: bool,
    /// Fleet worker-lease duration in seconds: heartbeats renew it, and
    /// a worker silent past it is lost — its running trials requeue.
    /// `None` disables lease expiry.
    pub lease_timeout: Option<f64>,
    /// Default max concurrently leased trials per site (0 = unlimited).
    pub site_quota: u32,
    /// Per-site quota overrides (`site → quota`; explicit 0 = unlimited
    /// for that site), beating `site_quota`.
    pub site_quota_map: HashMap<String, u32>,
    /// Max concurrently leased trials per study (0 = unlimited).
    pub study_quota: u32,
    /// Default max concurrently leased trials per tenant — the identity
    /// behind the auth token on the ask (0 = unlimited).
    pub tenant_quota: u32,
    /// Per-tenant quota overrides (`tenant → quota`).
    pub tenant_quota_map: HashMap<String, u32>,
    /// Max worker-less (legacy, lease-less) asks per tenant within the
    /// sliding ask-rate window (0 = unlimited). Lease quotas cannot
    /// bound clients that never hold a lease; this ledger closes that
    /// bypass.
    pub tenant_ask_rate: u32,
    /// Sliding window of the worker-less ask-rate ledger, seconds.
    pub tenant_ask_window: f64,
    /// Seconds a fair-share *waiting* mark lives: an abandoned denied
    /// campaign stops deflating other studies' share after this long.
    /// Also the grace before site affinity stops deferring a queued
    /// trial to healthier sites.
    pub fairness_horizon: f64,
    /// Prefer healthier sites when handing out requeued trials.
    pub site_affinity: bool,
    /// Times a trial may lose its worker and be requeued before the
    /// engine fails it for good.
    pub requeue_max: u32,
    /// Retired workers kept for attribution before the fleet GC drops
    /// them (`--dead-worker-keep`).
    pub dead_worker_keep: usize,
    /// Idle-site eviction window for the fleet GC, seconds
    /// (`--site-idle-retention`).
    pub site_idle_retention: f64,
    /// Reuse a study's cached sampler fit across asks while no tell has
    /// landed (`--sampler-cache off` disables reuse — every ask refits
    /// from the history window, the pre-cache behavior; the suggestion
    /// stream is byte-identical either way, see `Sampler::suggest`).
    pub sampler_cache: bool,
    /// Retained-trace ring-buffer slots (`--trace-capacity`; 0 turns
    /// request tracing off entirely).
    pub trace_capacity: usize,
    /// Head-sampling fraction of requests whose trace is retained
    /// (`--trace-sample`, 0.0–1.0).
    pub trace_sample: f64,
    /// Requests at least this slow are always retained, sampling aside
    /// (`--trace-slow-ms`; 0 disables slow-op capture).
    pub trace_slow_ms: u64,
    /// Emit one structured JSON log line per retained request
    /// (`--log-json`).
    pub log_json: bool,
    /// Run as a read-only follower: no group-commit writer is started;
    /// state arrives through [`Engine::apply_repl_batch`] and every
    /// mutating API returns [`ApiError::ReadOnly`] until
    /// [`Engine::promote`] flips the node writable.
    pub follower: bool,
    /// Primary URL hint carried inside read-only rejections (follower
    /// role).
    pub primary_url: Option<String>,
    /// Records retained in the primary's in-memory replication buffer.
    /// A follower that falls further behind than this window gets
    /// `TooOld` and must re-bootstrap from a snapshot bundle.
    pub repl_buffer: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            seed: 0x4f50_5441_4153,
            compact_after: 50_000,
            compact_threads: 0,
            reap_after: Some(3600.0),
            history_snapshot: 2048,
            n_shards: 8,
            wal_batch_max: 256,
            replay_threads: 0,
            wal_batch_adaptive: true,
            lease_timeout: Some(60.0),
            site_quota: 0,
            site_quota_map: HashMap::new(),
            study_quota: 0,
            tenant_quota: 0,
            tenant_quota_map: HashMap::new(),
            tenant_ask_rate: 0,
            tenant_ask_window: 60.0,
            fairness_horizon: 30.0,
            site_affinity: false,
            requeue_max: 3,
            dead_worker_keep: 1024,
            site_idle_retention: 3600.0,
            sampler_cache: true,
            trace_capacity: 2048,
            trace_sample: 1.0,
            trace_slow_ms: 250,
            log_json: false,
            follower: false,
            primary_url: None,
            repl_buffer: 65_536,
        }
    }
}

/// Largest `n` accepted by a batched ask (`{"n": k}` in the body).
pub const MAX_ASK_BATCH: usize = 64;

/// Response of a successful `ask`.
#[derive(Clone, Debug)]
pub struct AskReply {
    pub trial_id: u64,
    pub trial_number: u64,
    pub study_id: u64,
    pub study_key: String,
    pub params: Value,
    /// True when this is a previously issued trial re-homed after its
    /// worker was lost (same id/number/params as the original handout).
    pub requeued: bool,
}

/// State owned by one shard, guarded by the shard's lock.
struct ShardState {
    studies: Vec<Study>,
    /// study key → slot, for the keys this shard owns.
    by_key: HashMap<String, usize>,
    /// trial id → (slot, trial index) for trials on this shard.
    trial_index: HashMap<u64, (usize, usize)>,
    /// trial id → last report wall time (not persisted; reaping is a
    /// liveness heuristic, not state). Entries are removed when the
    /// trial reaches a terminal state, so long campaigns don't leak.
    last_seen: HashMap<u64, f64>,
}

struct Shard {
    state: Mutex<ShardState>,
}

/// One unit of parallel recovery: the studies and events of a
/// study-disjoint slice of the recovered state, in file order. Built by
/// `Engine::plan_replay`, applied by one thread in
/// `Engine::apply_partitions`.
struct ReplayPartition {
    studies: Vec<Study>,
    events: Vec<Record>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            state: Mutex::new(ShardState {
                studies: Vec::new(),
                by_key: HashMap::new(),
                trial_index: HashMap::new(),
                last_seen: HashMap::new(),
            }),
        }
    }
}

/// The coordination engine. Thread-safe; the HTTP layer shares it.
pub struct Engine {
    shards: Vec<Shard>,
    directory: RwLock<Directory>,
    router: TrialRouter,
    next_trial_id: AtomicU64,
    next_study_id: AtomicU64,
    /// Group-commit writer; unset for in-memory engines and for
    /// followers (where [`Engine::promote`] installs it exactly once).
    wal: OnceLock<GroupWal>,
    /// Records appended since the last compaction (compaction policy).
    wal_records: AtomicU64,
    /// `wal_records` threshold at which auto-compaction next fires.
    /// Normally `config.compact_after`; raised after a failed attempt so
    /// a persistently failing snapshot (e.g. disk full) doesn't turn
    /// every mutation into a retry storm.
    compact_threshold: AtomicU64,
    /// Guard against concurrent compaction stampedes.
    compacting: AtomicBool,
    /// Serializes whole compactions: the begin/cut-per-shard/finish
    /// phases of two drivers must never interleave on the writer thread.
    compact_lock: Mutex<()>,
    /// What the last recovery pass observed (zeros for in-memory
    /// engines); surfaced via `/api/stats` and `/metrics`.
    recovery: RecoveryStats,
    /// The fleet tables: worker registry, lease table, site scheduler.
    /// A leaf lock — may be taken under a shard lock, never the reverse.
    fleet: Fleet,
    /// Set once any fleet state exists (a registration, or recovered
    /// workers/leases). Until then the tell/fail/prune hot paths skip
    /// the global fleet mutex entirely, so a worker-less deployment
    /// keeps the sharded engine free of cross-shard serialization.
    /// Never reset: one registration makes the fleet live for good.
    fleet_active: AtomicBool,
    /// Guards lease handouts against the fleet segment cut. Binds ride
    /// the *shard* lock (so they batch with their `trial_new`) rather
    /// than the fleet lock — this gate is what makes the fleet cut
    /// exact anyway: every handout holds a read lock from before its
    /// requeue-queue pop (or WAL append) through its in-memory apply,
    /// and compaction holds the write lock across snapshot + cut, so
    /// the cut can never observe a trial mid-handout nor cover a bind
    /// the snapshot lacks. Ordering: bind gate → shard lock → fleet
    /// lock (the gate is always outermost).
    fleet_bind_gate: RwLock<()>,
    /// Records appended per shard since that shard's last segment cut.
    /// Compaction skips re-cutting a shard whose counter is 0 (the
    /// previous segment still covers it exactly).
    shard_dirty: Vec<AtomicU64>,
    /// Same, for the fleet's [`FLEET_SHARD`] records.
    fleet_dirty: AtomicU64,
    config: EngineConfig,
    start: Instant,
    pub metrics: Arc<Metrics>,
    /// Materialized read views + the trial feed, published by the
    /// mutation paths under their shard lock (see `views.rs` for the
    /// epoch-stamping rule) and read by the HTTP layer without ever
    /// touching a shard lock.
    views: Arc<ViewRegistry>,
    /// Request-tracing subsystem: span retention ring, slow-op
    /// exemplars, structured log. Shared with the HTTP server, which
    /// opens/closes the spans around router dispatch.
    tracer: Arc<Tracer>,
    /// Total asks served (for quick health output).
    asks: AtomicU64,
    /// False on followers until [`Engine::promote`] flips it; every
    /// mutating API checks this first.
    writable: AtomicBool,
    /// Primary-side replication buffer; set when the group-commit
    /// writer starts (open, or promote), never on pure followers.
    repl_source: OnceLock<Arc<ReplicationSource>>,
    /// Follower-side: the raw storage the applier appends shipped
    /// records into (with their primary seqs). Taken by `promote`, which
    /// starts the group-commit writer over it.
    follower_store: Mutex<Option<Storage>>,
    /// Follower-side: per-shard manifest cuts from the bootstrap bundle.
    /// Shipped records below their shard's cut are already covered by
    /// the installed segments and must not be re-applied or re-appended.
    repl_cuts: HashMap<u32, u64>,
    /// Follower-side: next replication seq this node needs.
    repl_next: AtomicU64,
    /// Follower-side: the primary's `next_seq` as of the last batch —
    /// the lag denominator.
    repl_primary_next: AtomicU64,
    /// Follower-side: wall-clock ms of the moment we last stopped being
    /// caught up (0 = currently caught up). Drives
    /// `hopaas_repl_lag_seconds`.
    repl_behind_since_ms: AtomicU64,
}

impl Engine {
    /// In-memory engine (tests, benches).
    pub fn in_memory(config: EngineConfig) -> Engine {
        let n = config.n_shards.max(1);
        let fleet_config = FleetConfig {
            lease_timeout: config.lease_timeout,
            requeue_max: config.requeue_max,
            dead_worker_keep: config.dead_worker_keep,
            site_idle_retention: config.site_idle_retention,
            policy: crate::fleet::QuotaPolicy {
                site_quota: config.site_quota,
                site_quotas: config.site_quota_map.clone(),
                study_quota: config.study_quota,
                tenant_quota: config.tenant_quota,
                tenant_quotas: config.tenant_quota_map.clone(),
                tenant_ask_rate: config.tenant_ask_rate,
                tenant_ask_window: config.tenant_ask_window,
                fairness_horizon: config.fairness_horizon,
                site_affinity: config.site_affinity,
            },
        };
        let metrics = Arc::new(Metrics::with_shards(n));
        let tracer = Arc::new(Tracer::new(TracerConfig {
            capacity: config.trace_capacity,
            sample: config.trace_sample.clamp(0.0, 1.0),
            slow_ms: config.trace_slow_ms,
            log_json: config.log_json,
        }));
        let writable = !config.follower;
        Engine {
            shards: (0..n).map(|_| Shard::new()).collect(),
            directory: RwLock::new(Directory::default()),
            router: TrialRouter::default(),
            next_trial_id: AtomicU64::new(1),
            next_study_id: AtomicU64::new(1),
            wal: OnceLock::new(),
            wal_records: AtomicU64::new(0),
            compact_threshold: AtomicU64::new(config.compact_after),
            compacting: AtomicBool::new(false),
            compact_lock: Mutex::new(()),
            recovery: RecoveryStats::default(),
            fleet: Fleet::new(fleet_config),
            fleet_active: AtomicBool::new(false),
            fleet_bind_gate: RwLock::new(()),
            shard_dirty: (0..n).map(|_| AtomicU64::new(0)).collect(),
            fleet_dirty: AtomicU64::new(0),
            config,
            start: Instant::now(),
            views: Arc::new(ViewRegistry::new(metrics.clone())),
            tracer,
            metrics,
            asks: AtomicU64::new(0),
            writable: AtomicBool::new(writable),
            repl_source: OnceLock::new(),
            follower_store: Mutex::new(None),
            repl_cuts: HashMap::new(),
            repl_next: AtomicU64::new(0),
            repl_primary_next: AtomicU64::new(0),
            repl_behind_since_ms: AtomicU64::new(0),
        }
    }

    /// The materialized-view registry (the HTTP read path and the
    /// parked-reader pump wire themselves to it).
    pub fn views(&self) -> &Arc<ViewRegistry> {
        &self.views
    }

    /// The request-tracing subsystem (the HTTP server opens spans with
    /// it; the trace API reads from it).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Durable engine: replays segments + WAL from `dir` (in parallel,
    /// partitioned by study), then starts the group-commit writer over
    /// the same storage.
    pub fn open(dir: impl AsRef<std::path::Path>, config: EngineConfig) -> Result<Engine, ApiError> {
        let storage = Storage::open(dir).map_err(|e| ApiError::Storage(e.to_string()))?;
        Engine::open_with_storage(storage, config)
    }

    /// As [`Engine::open`] over an already-opened [`Storage`] — the seam
    /// the crash-injection harness uses to plant fault hooks.
    ///
    /// Recovery runs in three steps:
    /// 1. `storage.load()` reads the manifest/segments (or the legacy v1
    ///    snapshot) and replays every surviving log in epoch order,
    ///    filtering out records the manifest proves are covered;
    /// 2. the planner partitions segment studies *and* events by study
    ///    key — records of one study always land in one partition, in
    ///    file order, whatever shard count wrote them — and each
    ///    partition replays on its own thread;
    /// 3. the global commit `seq` order is verified during load, and the
    ///    writer resumes from `max(manifest.next_seq, max(seq)+1)`.
    pub fn open_with_storage(
        mut storage: Storage,
        config: EngineConfig,
    ) -> Result<Engine, ApiError> {
        let loaded = storage.load().map_err(|e| ApiError::Storage(e.to_string()))?;
        let mut engine = Engine::in_memory(config);

        // Resume id/seq allocation. `fetch_max` per recovered study and
        // trial also runs during replay; the manifest and legacy
        // snapshot carry explicit high-water marks on top.
        if let Some(m) = &loaded.manifest {
            engine
                .next_trial_id
                .fetch_max(m.get("next_trial_id").as_u64().unwrap_or(1), Ordering::Relaxed);
            engine
                .next_study_id
                .fetch_max(m.get("next_study_id").as_u64().unwrap_or(1), Ordering::Relaxed);
        }
        if let Some(snap) = &loaded.snapshot {
            engine
                .next_trial_id
                .fetch_max(snap.get("next_trial_id").as_u64().unwrap_or(1), Ordering::Relaxed);
        }
        let manifest_next_seq = loaded
            .manifest
            .as_ref()
            .map(|m| m.get("next_seq").as_u64().unwrap_or(0))
            .unwrap_or(0);
        let event_next_seq = loaded.events.iter().map(|r| r.seq + 1).max().unwrap_or(0);
        let next_seq = manifest_next_seq.max(event_next_seq);

        // Seed the clean-shard reuse table from the manifest — but only
        // when its segment set matches the current layout exactly (one
        // segment per live shard): placement is `fnv1a(key) % n`, so a
        // different shard count re-homes studies and every old segment
        // becomes unusable as-is. The fleet segment is layout-free and
        // always reusable.
        let mut prev_segments: HashMap<u32, (String, u64)> = HashMap::new();
        if let Some(m) = &loaded.manifest {
            let segs = m.get("segments").as_arr().unwrap_or(&[]).to_vec();
            let mut by_shard: HashMap<u32, (String, u64)> = HashMap::new();
            for seg in &segs {
                if let (Some(shard), Some(file)) =
                    (seg.get("shard").as_u64(), seg.get("file").as_str())
                {
                    by_shard.insert(
                        shard as u32,
                        (file.to_string(), seg.get("next_seq").as_u64().unwrap_or(0)),
                    );
                }
            }
            let study_shards: Vec<u32> =
                by_shard.keys().copied().filter(|&s| s != FLEET_SHARD).collect();
            let layout_matches = study_shards.len() == engine.shards.len()
                && study_shards.iter().all(|&s| (s as usize) < engine.shards.len());
            if layout_matches {
                prev_segments = by_shard;
            } else if let Some(fleet_seg) = by_shard.remove(&FLEET_SHARD) {
                prev_segments.insert(FLEET_SHARD, fleet_seg);
            }
        }

        // Replication bookkeeping, captured before `plan_replay`
        // consumes `loaded`:
        //  - per-shard manifest cuts (a follower's bundle covers every
        //    record below its shard's cut; shipped records below it are
        //    skipped, and the primary's log floor starts at the lowest
        //    cut);
        //  - the uncovered event tail, which seeds the primary's
        //    replication buffer so a follower that was only a little
        //    behind at primary-restart can still tail the log instead
        //    of re-bootstrapping.
        let mut repl_cuts: HashMap<u32, u64> = HashMap::new();
        if let Some(m) = &loaded.manifest {
            for seg in m.get("segments").as_arr().unwrap_or(&[]) {
                if let Some(shard) = seg.get("shard").as_u64() {
                    repl_cuts.insert(shard as u32, seg.get("next_seq").as_u64().unwrap_or(0));
                }
            }
        }
        let min_cut = repl_cuts.values().copied().min().unwrap_or(0);
        let repl_tail: Vec<Record> = loaded.events.clone();
        // The tail only seeds the buffer when it is a contiguous,
        // strictly increasing seq run below `next_seq` — the legacy-v1
        // snapshot path can violate that, in which case the log floor
        // starts at `next_seq` and cold followers must bootstrap.
        let tail_monotonic = repl_tail.windows(2).all(|w| w[0].seq < w[1].seq)
            && repl_tail.last().map(|r| r.seq < next_seq).unwrap_or(true);

        // Fleet segment (engine-global; not partitioned by study).
        let fleet_snapshot: Option<Value> = loaded
            .segments
            .iter()
            .find(|s| s.get("shard").as_u64() == Some(FLEET_SHARD as u64))
            .map(|s| s.get("studies").clone());

        let mut recovery = loaded.stats;
        let (parts, fleet_events) = engine.plan_replay(loaded, &mut recovery)?;
        engine.apply_partitions(parts);
        if let Some(snap) = &fleet_snapshot {
            engine.fleet.lock().load_snapshot(snap);
        }
        for rec in &fleet_events {
            engine.apply_fleet_event(rec);
        }
        engine.finish_fleet_recovery();
        // Recovery replays trials directly into the shards; build the
        // read views from the recovered state in one deterministic pass
        // (slot-ordered trials, `(finished_at, id)`-ordered feed).
        engine.rebuild_views();
        engine.recovery = recovery;
        engine
            .wal_records
            .store(recovery.recovered_records, Ordering::Relaxed);
        // Any recovered log record makes every shard (and the fleet)
        // dirty for reuse purposes: the previous segments no longer
        // cover the live state, so the first compaction cuts in full.
        if recovery.recovered_records > 0 {
            for d in &engine.shard_dirty {
                d.store(recovery.recovered_records, Ordering::Relaxed);
            }
        }
        if !fleet_events.is_empty() {
            engine
                .fleet_dirty
                .store(fleet_events.len() as u64, Ordering::Relaxed);
        }
        engine.refresh_storage_metrics();

        if engine.config.follower {
            // Followers never start the group-commit writer: shipped
            // records keep their primary seqs, and the applier appends
            // them to the raw storage itself. Resume from the last
            // locally durable record (or the bundle's lowest cut for a
            // cold install).
            let resume = if event_next_seq > 0 { event_next_seq } else { min_cut };
            engine.repl_cuts = repl_cuts;
            engine.repl_next.store(resume, Ordering::Relaxed);
            engine.repl_primary_next.store(resume, Ordering::Relaxed);
            *engine.follower_store.lock_safe() = Some(storage);
        } else {
            let source = Arc::new(ReplicationSource::new(
                engine.config.repl_buffer,
                if tail_monotonic { min_cut } else { next_seq },
                next_seq,
                if tail_monotonic { repl_tail } else { Vec::new() },
                engine.views.signal(),
            ));
            let _ = engine.repl_source.set(source.clone());
            let _ = engine.wal.set(GroupWal::start(
                storage,
                engine.wal_config(),
                next_seq,
                prev_segments,
                Some(source),
            ));
        }
        Ok(engine)
    }

    fn wal_config(&self) -> GroupWalConfig {
        GroupWalConfig {
            batch_max: self.config.wal_batch_max.max(1),
            adaptive: self.config.wal_batch_adaptive,
            ..GroupWalConfig::default()
        }
    }

    /// Post-replay fleet pass: drop leases and queue entries whose
    /// trial is no longer running (its terminal record replayed after
    /// the bind), rebuild the scheduler counts, and grant every alive
    /// worker a fresh lease window — deadlines are liveness, not state,
    /// so a recovering server gives live workers one heartbeat interval
    /// before expiry requeues their trials.
    fn finish_fleet_recovery(&self) {
        let tracked: Vec<u64> = {
            let fl = self.fleet.lock();
            if fl.registry.is_empty() && fl.leases.is_empty() {
                return;
            }
            self.fleet_active.store(true, Ordering::Relaxed);
            fl.leases.all_tracked().into_iter().map(|(tid, _)| tid).collect()
        };
        let mut running: HashSet<u64> = HashSet::new();
        for tid in tracked {
            let Some(shard_idx) = self.router.get(tid) else { continue };
            let guard = self.lock_shard(shard_idx);
            if let Some(&(si, ti)) = guard.trial_index.get(&tid) {
                if guard.studies[si].trials[ti].state == TrialState::Running {
                    running.insert(tid);
                }
            }
        }
        let now = self.now();
        let ttl = self.fleet.ttl();
        let mut fl = self.fleet.lock();
        fl.scrub(&running);
        fl.registry.reset_deadlines(now, ttl);
    }

    /// Replay one fleet record (worker registry / lease events). These
    /// are applied sequentially after the study partitions finish: they
    /// are engine-global, cheap, and their relative order matters.
    fn apply_fleet_event(&self, rec: &Record) {
        let v = &rec.payload;
        let mut fl = self.fleet.lock();
        match rec.tag.as_str() {
            "worker_register" => {
                if let Some(id) = v.get("id").as_u64() {
                    fl.registry.apply_register(
                        id,
                        v.get("name").as_str().unwrap_or(""),
                        v.get("site").as_str().unwrap_or(""),
                        v.get("gpu").as_str().unwrap_or(""),
                        v.get("at").as_f64().unwrap_or(0.0),
                        0.0,
                    );
                }
            }
            "worker_lost" => {
                if let Some(id) = v.get("worker_id").as_u64() {
                    fl.registry.mark_lost(id, v.get("at").as_f64().unwrap_or(0.0));
                }
            }
            "worker_deregister" => {
                if let Some(id) = v.get("worker_id").as_u64() {
                    fl.registry.mark_deregistered(id);
                }
            }
            "lease_bind" => {
                if let (Some(tid), Some(wid), Some(key)) = (
                    v.get("trial_id").as_u64(),
                    v.get("worker_id").as_u64(),
                    v.get("study_key").as_str(),
                ) {
                    fl.apply_bind(
                        tid,
                        wid,
                        key,
                        v.get("site").as_str().unwrap_or(""),
                        v.get("tenant").as_str(),
                        v.get("at").as_f64().unwrap_or(0.0),
                    );
                }
            }
            "trial_requeue" => {
                if let (Some(tid), Some(key)) =
                    (v.get("trial_id").as_u64(), v.get("study_key").as_str())
                {
                    fl.apply_requeue(tid, key);
                }
            }
            "site_loss" => {
                // A requeue-budget exhaustion charged the site's health
                // ledger without a trial_requeue record (the trial was
                // failed, not queued) — replay the charge.
                if let Some(site) = v.get("site").as_str() {
                    fl.sched.note_loss(site);
                }
            }
            _ => {}
        }
    }

    /// Recovery statistics of the last [`Engine::open`] (zeros for
    /// in-memory engines).
    pub fn recovery_stats(&self) -> RecoveryStats {
        self.recovery
    }

    // ----- replication: primary log, follower apply, promote -----

    /// Whether this node accepts writes (primaries always; followers
    /// only after [`Engine::promote`]).
    pub fn is_writable(&self) -> bool {
        self.writable.load(Ordering::Acquire)
    }

    fn check_writable(&self) -> Result<(), ApiError> {
        if self.is_writable() {
            Ok(())
        } else {
            Err(ApiError::ReadOnly(self.config.primary_url.clone()))
        }
    }

    /// The primary-side replication buffer (`None` on un-promoted
    /// followers and in-memory engines).
    pub fn repl_source(&self) -> Option<Arc<ReplicationSource>> {
        self.repl_source.get().cloned()
    }

    /// Follower cursor: the next replication seq this node needs.
    pub fn repl_next(&self) -> u64 {
        self.repl_next.load(Ordering::Acquire)
    }

    /// The primary's `next_seq` as of the last applied batch (the lag
    /// denominator; equals the cursor when caught up).
    pub fn repl_primary_next(&self) -> u64 {
        self.repl_primary_next.load(Ordering::Acquire)
    }

    /// Follower-side apply: append a run of shipped records (in primary
    /// seq order) to local storage, replay them through the recovery
    /// apply path, and rebuild the touched studies' read views + event
    /// logs. Returns the new cursor (last applied seq + 1).
    ///
    /// Idempotent across reconnect overlap: records below the cursor
    /// are dropped, and records below their shard's bootstrap-bundle
    /// cut are already covered by the installed segments (the cursor
    /// advances past them without re-applying).
    pub fn apply_repl_batch(&self, records: &[Record], primary_next: u64) -> Result<u64, ApiError> {
        // The store lock doubles as the apply serialization point:
        // promote holds it while flipping writable, so a batch can
        // never land half-applied across the promotion boundary.
        let mut store_guard = self.follower_store.lock_safe();
        if self.is_writable() {
            return Err(ApiError::Conflict("replication sealed: node is writable".into()));
        }
        // lint:allow(determinism): span timing only — never applied state.
        let t0 = Instant::now();
        let mut cursor = self.repl_next.load(Ordering::Acquire);
        let mut studies_touched: HashSet<u64> = HashSet::new();
        let mut trials_touched: Vec<(u32, u64)> = Vec::new();
        let mut appended = 0u64;
        for rec in records {
            if rec.seq < cursor {
                continue;
            }
            cursor = rec.seq + 1;
            if rec.seq < self.repl_cuts.get(&rec.shard).copied().unwrap_or(0) {
                continue;
            }
            if let Some(store) = store_guard.as_mut() {
                store
                    .append_nosync(rec)
                    .map_err(|e| ApiError::Storage(e.to_string()))?;
                appended += 1;
            }
            if Self::is_fleet_tag(&rec.tag) {
                self.apply_fleet_event(rec);
                self.fleet_active.store(true, Ordering::Relaxed);
            } else {
                self.apply_event(rec);
                let v = &rec.payload;
                match rec.tag.as_str() {
                    "study_new" => {
                        if let Some(id) = v.get("id").as_u64() {
                            studies_touched.insert(id);
                        }
                    }
                    "trial_new" => {
                        if let Some(id) = v.get("study_id").as_u64() {
                            studies_touched.insert(id);
                        }
                    }
                    _ => {
                        if let Some(tid) = v.get("trial_id").as_u64() {
                            trials_touched.push((rec.shard, tid));
                        }
                    }
                }
            }
            self.wal_records.fetch_add(1, Ordering::Relaxed);
            self.note_dirty(rec.shard, 1);
        }
        if appended > 0 {
            if let Some(store) = store_guard.as_mut() {
                // lint:allow(guard_blocking): the store lock IS the
                // apply/promote serialization point — promote must not
                // flip writable between this batch's append and fsync.
                store.sync().map_err(|e| ApiError::Storage(e.to_string()))?;
            }
        }
        for (shard, tid) in trials_touched {
            let idx = shard as usize;
            if idx >= self.shards.len() {
                continue;
            }
            let guard = self.lock_shard(idx);
            if let Some(&(si, _)) = guard.trial_index.get(&tid) {
                studies_touched.insert(guard.studies[si].id);
            }
        }
        let changed = !studies_touched.is_empty();
        for id in studies_touched {
            let Some(entry) = ({ self.directory.read_safe().lookup(id) }) else {
                continue;
            };
            let guard = self.lock_shard(entry.shard);
            self.views.rebuild_from(&guard.studies[entry.slot]);
        }
        self.repl_next.store(cursor, Ordering::Release);
        self.repl_primary_next.fetch_max(primary_next.max(cursor), Ordering::AcqRel);
        if cursor >= self.repl_primary_next.load(Ordering::Acquire) {
            self.repl_behind_since_ms.store(0, Ordering::Relaxed);
        } else {
            // lint:allow(determinism): replication-lag gauge only —
            // feeds `/api/stats`, never the applied state.
            let now_ms = (self.now() * 1000.0) as u64;
            let _ = self.repl_behind_since_ms.compare_exchange(
                0,
                now_ms.max(1),
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
        }
        if changed {
            // `rebuild_from` publishes silently; wake the parked
            // events readers ourselves, once per batch.
            self.views.signal().notify_all();
        }
        obs::stage(Stage::ReplApply, t0.elapsed());
        Ok(cursor)
    }

    /// Flip a follower writable — exactly once — and start the
    /// group-commit writer over the locally accumulated log, so new
    /// writes are durable and shippable to the next generation of
    /// followers. The previous-segment table starts empty: the first
    /// compaction after promotion cuts every shard in full.
    ///
    /// The caller (the promote route) seals the applier and replays the
    /// residual tail *before* calling this; any replication batch that
    /// arrives afterwards is rejected with `Conflict`.
    pub fn promote(&self) -> Result<u64, ApiError> {
        let mut store_guard = self.follower_store.lock_safe();
        if self
            .writable
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return Err(ApiError::Conflict("node is already writable".into()));
        }
        let next = self.repl_next.load(Ordering::Acquire);
        if let Some(storage) = store_guard.take() {
            let source = Arc::new(ReplicationSource::new(
                self.config.repl_buffer,
                next,
                next,
                Vec::new(),
                self.views.signal(),
            ));
            let _ = self.repl_source.set(source.clone());
            let _ = self.wal.set(GroupWal::start(
                storage,
                self.wal_config(),
                next,
                HashMap::new(),
                Some(source),
            ));
        }
        self.repl_behind_since_ms.store(0, Ordering::Relaxed);
        // Mirror `finish_fleet_recovery`: deadlines are liveness, not
        // state — grant every alive worker one fresh TTL window before
        // expiry starts requeueing their trials on the new primary.
        {
            let now = self.now();
            let ttl = self.fleet.ttl();
            let mut fl = self.fleet.lock();
            if !fl.registry.is_empty() || !fl.leases.is_empty() {
                self.fleet_active.store(true, Ordering::Relaxed);
                fl.registry.reset_deadlines(now, ttl);
            }
        }
        self.refresh_storage_metrics();
        Ok(next)
    }

    /// Seconds since engine start — the time base used across the
    /// coordinator.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Number of shards (diagnostics).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard owning a study key: stable hash placement.
    fn shard_of(&self, key: &str) -> usize {
        place(key, self.shards.len())
    }

    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, ShardState> {
        // The lock wait (not the hold) is what a traced request paid to
        // other requests; record it only when a span is active so the
        // bare path stays two instructions.
        if obs::active() {
            let t0 = Instant::now();
            let guard = self.shards[idx].state.lock_safe();
            obs::stage(Stage::ShardLock, t0.elapsed());
            guard
        } else {
            self.shards[idx].state.lock_safe()
        }
    }

    /// Route a trial id to its shard or produce the API error.
    fn route(&self, trial_id: u64) -> Result<usize, ApiError> {
        self.router
            .get(trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))
    }

    // ------------------------------------------------------------------
    // Table 1 APIs
    // ------------------------------------------------------------------

    /// `ask`: create a trial in the study defined by `body`; returns the
    /// suggested hyperparameters.
    ///
    /// Locking (§Perf): the surrogate refit (TPE KDE / GP Cholesky) is
    /// the expensive part of an ask, so it runs on a *snapshot* of the
    /// study history taken under the shard lock, with the lock released.
    /// A concurrent ask may therefore suggest from history that is one
    /// or two tells stale — the same semantics Optuna has in distributed
    /// mode, and irrelevant statistically (the history grows by whole
    /// trials, the surrogate by one observation). The trial *number*,
    /// however, is reserved inside the first critical section: it seeds
    /// the suggestion RNG, so two asks racing on the same study must
    /// draw distinct numbers or they would draw identical suggestions.
    /// The shard lock is re-taken only to insert the trial record.
    pub fn ask(&self, body: &Value) -> Result<AskReply, ApiError> {
        self.ask_as(body, None)
    }

    /// `ask` with the caller's tenant identity (the `user` claim of the
    /// auth token presented on the request; `None` for unauthenticated
    /// or legacy callers). Tenant quotas bind leases, so they apply to
    /// worker-bound asks — the only ones that hold fleet slots.
    pub fn ask_as(&self, body: &Value, tenant: Option<&str>) -> Result<AskReply, ApiError> {
        self.ask_n_as(body, 1, tenant).map(|mut v| v.remove(0))
    }

    /// Batched `ask`: reserve `n` trials of the study in one request.
    /// One shard-lock acquisition reserves all `n` numbers and one
    /// sampler fit amortizes over the whole batch, but each suggestion
    /// still draws from its own trial-number-seeded RNG — the reply
    /// stream is byte-identical to `n` sequential single asks.
    ///
    /// Error contract: `Err` means *zero* trials were created. When the
    /// batch partially succeeds (e.g. requeued trials were handed out
    /// before a storage error), the created prefix is returned as `Ok`
    /// with fewer than `n` entries — the caller sees exactly which
    /// trials exist.
    pub fn ask_n_as(
        &self,
        body: &Value,
        n: usize,
        tenant: Option<&str>,
    ) -> Result<Vec<AskReply>, ApiError> {
        self.check_writable()?;
        if n == 0 || n > MAX_ASK_BATCH {
            return Err(ApiError::BadRequest(format!(
                "'n' must be between 1 and {MAX_ASK_BATCH}, got {n}"
            )));
        }
        let (def, node) = parse_ask_body(body).map_err(ApiError::BadRequest)?;
        // Reject unknown sampler names before any side effects: the
        // study (and its quota slots) must not be created for an ask
        // that can never suggest. MO studies resolve names differently
        // (`ask_mo` validates nsga2 + the plain subset itself).
        if !def.is_mo() && !is_known_sampler(&def.sampler.name) {
            return Err(ApiError::BadRequest(format!(
                "unknown sampler '{}'",
                def.sampler.name
            )));
        }
        let worker = body.get("worker").as_u64();
        let now = self.now();
        let key = def.key();
        self.metrics.ask_batch_size.observe(n as f64);
        // Attribute the span before any admission decision so even a
        // quota-denied ask carries tenant/worker identity in the trace.
        if obs::active() {
            if let Some(t) = tenant {
                obs::set_tenant(t);
            }
            if let Some(wid) = worker {
                obs::set_worker(&wid.to_string());
            }
        }
        let admit_t0 = if obs::active() { Some(Instant::now()) } else { None };
        // Worker-less (legacy) asks never hold a lease, so the lease
        // quotas cannot bound them — the sliding per-tenant ask-rate
        // ledger does, checked before any sampling work. Each trial of
        // the batch costs one ledger slot, same as `n` sequential asks.
        if worker.is_none() {
            if let Some(t) = tenant {
                for _ in 0..n {
                    if let Err(e) = self.fleet.note_legacy_ask(t, now) {
                        self.metrics.fleet_quota_denials.inc();
                        if crate::fleet::scheduler::is_tenant_denial(&e) {
                            self.metrics.inc_tenant_denial(t);
                        }
                        return Err(e);
                    }
                }
            }
        }
        // Fleet admission: a worker-bound ask reserves one scheduling
        // slot per trial (site + study + tenant quotas, fair share)
        // before any sampling work. Slots become leases on success and
        // are all returned on error — a batch is admitted whole or not
        // at all. `admit` hands back the site each slot was counted
        // under; it is threaded through to the bind (or the cancel) so
        // the ledger stays exact even if the worker is GC'd mid-ask.
        let mut admitted: Vec<String> = Vec::new();
        if let Some(wid) = worker {
            for _ in 0..n {
                // Bind the admit result to a local: a `match` scrutinee
                // keeps its temporaries (here the fleet guard) alive for
                // every arm, and the `Err` arm re-locks the fleet to
                // return earlier slots — scrutinizing the guard directly
                // self-deadlocks on the partial-batch denial path.
                let admit =
                    self.fleet.lock().admit(wid, &key, tenant, now, &self.fleet.config);
                match admit {
                    Ok(site) => admitted.push(site),
                    Err(e) => {
                        if matches!(e, ApiError::Quota(_)) {
                            self.metrics.fleet_quota_denials.inc();
                            // Only tenant-*rule* denials feed the
                            // per-tenant series: a tenanted ask refused
                            // on site capacity is site back-pressure,
                            // not a tenant budget problem.
                            if let Some(t) = tenant {
                                if crate::fleet::scheduler::is_tenant_denial(&e) {
                                    self.metrics.inc_tenant_denial(t);
                                }
                            }
                        }
                        for site in &admitted {
                            self.fleet.lock().cancel_admission(site, &key, tenant);
                        }
                        return Err(e);
                    }
                }
            }
        }
        if let Some(t0) = admit_t0 {
            obs::stage(Stage::Admission, t0.elapsed());
            if let Some(site) = admitted.first() {
                obs::set_site(site);
            }
        }
        let result = self.ask_admitted_n(def, node, now, &key, worker, tenant, &admitted, n);
        // Return every admission slot the batch did not consume: all of
        // them on `Err` (zero trials created), the unused tail on a
        // partial `Ok` (each reply — requeued or fresh — consumed one).
        match &result {
            Ok(replies) => {
                for site in admitted.iter().skip(replies.len()) {
                    self.fleet.lock().cancel_admission(site, &key, tenant);
                }
            }
            Err(_) => {
                for site in &admitted {
                    self.fleet.lock().cancel_admission(site, &key, tenant);
                }
            }
        }
        result
    }

    /// The ask body once admission (if any) has been granted. Drains
    /// waiting requeued trials of the study first — re-homing them with
    /// their original ids, numbers and parameters — and samples fresh
    /// trials for the remainder of the batch.
    #[allow(clippy::too_many_arguments)]
    fn ask_admitted_n(
        &self,
        def: StudyDef,
        node: Option<String>,
        now: f64,
        key: &str,
        worker: Option<u64>,
        tenant: Option<&str>,
        sites: &[String],
        n: usize,
    ) -> Result<Vec<AskReply>, ApiError> {
        let mut replies: Vec<AskReply> = Vec::with_capacity(n);
        if let Some(wid) = worker {
            while replies.len() < n {
                let site = sites[replies.len()].as_str();
                match self.assign_requeued(key, wid, tenant, site, now) {
                    Ok(Some(reply)) => replies.push(reply),
                    Ok(None) => break,
                    // Partial-batch contract: `Err` only when nothing
                    // was handed out; otherwise the created prefix is
                    // the response and the caller returns unused slots.
                    Err(e) if replies.is_empty() => return Err(e),
                    Err(_) => return Ok(replies),
                }
            }
            if replies.len() == n {
                return Ok(replies);
            }
        }
        let fresh = n - replies.len();
        let key = key.to_string();
        if def.is_mo() {
            // MO asks refit NSGA-II per suggestion (its selection depends
            // on the whole objective-vector front, not a scalar window);
            // a batch is the sequential loop.
            for _ in 0..fresh {
                let site = worker.map(|_| sites[replies.len()].as_str());
                match self.ask_mo(def.clone(), node.clone(), now, key.clone(), worker, tenant, site)
                {
                    Ok(r) => replies.push(r),
                    Err(e) if replies.is_empty() => return Err(e),
                    Err(_) => return Ok(replies),
                }
            }
            return Ok(replies);
        }
        let done = replies.len();
        match self.ask_fresh_batch(&def, node, now, &key, worker, tenant, &sites[done..], fresh) {
            Ok(mut batch) => {
                replies.append(&mut batch);
                Ok(replies)
            }
            Err(e) if replies.is_empty() => Err(e),
            Err(_) => Ok(replies),
        }
    }

    /// Sample and insert `r` fresh single-objective trials in one pass:
    /// one critical section reserves the numbers and resolves the fit
    /// cache, one (possibly cached) fit serves every draw, and one
    /// critical section inserts all `r` trials under a single
    /// group-commit roundtrip.
    #[allow(clippy::too_many_arguments)]
    fn ask_fresh_batch(
        &self,
        def: &StudyDef,
        node: Option<String>,
        now: f64,
        key: &str,
        worker: Option<u64>,
        tenant: Option<&str>,
        sites: &[String],
        r: usize,
    ) -> Result<Vec<AskReply>, ApiError> {
        let shard_idx = self.shard_of(key);

        /// What critical section 1 resolved the history question to:
        /// nothing (the sampler never reads it), a cached fit (epoch
        /// unchanged since it was built), or an `Arc`-shared observation
        /// window to refit from outside the lock.
        enum HistoryArm {
            None,
            Fit(Arc<dyn FitState>),
            Snap(u64, Arc<Vec<Obs>>),
        }

        // --- critical section 1: find/create study, reserve the trial
        // numbers, resolve sampler + history ---
        let mut staged_dir: Option<DirEntry> = None;
        let (slot, numbers, sampler, arm, space, direction) = {
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            // Validate the sampler config before creating the study: an
            // ask with a broken sampler must not persist a half-usable
            // study, and `find_or_create_study` must be the last
            // fallible step under this guard — its staged directory
            // entry is published right after the guard drops, so no
            // early return may sit between the two.
            let prebuilt: Option<Arc<dyn Sampler>> = match state.by_key.get(key) {
                Some(&slot) if state.studies[slot].runtime.sampler.is_some() => None,
                _ => Some(Arc::from(make_sampler(&def.sampler).map_err(ApiError::BadRequest)?)),
            };
            let slot =
                self.find_or_create_study(state, shard_idx, def, now, key, &mut staged_dir)?;
            let study = &mut state.studies[slot];
            obs::set_study(study.id);
            let numbers: Vec<u64> = (0..r).map(|_| study.reserve_number()).collect();
            // The sampler is built once per study slot and shared across
            // asks (it is pure configuration; all mutable state lives in
            // the FitState).
            let sampler: Arc<dyn Sampler> = match (&study.runtime.sampler, prebuilt) {
                (Some(s), _) => Arc::clone(s),
                (None, Some(s)) => {
                    study.runtime.sampler = Some(Arc::clone(&s));
                    s
                }
                // Unreachable in practice: `prebuilt` is `None` only
                // when the slot already carried a cached sampler, and
                // both were read under this same guard.
                (None, None) => {
                    let s: Arc<dyn Sampler> =
                        Arc::from(make_sampler(&def.sampler).map_err(ApiError::BadRequest)?);
                    study.runtime.sampler = Some(Arc::clone(&s));
                    s
                }
            };
            let arm = if !sampler.needs_history() {
                HistoryArm::None
            } else {
                let epoch = study.runtime.epoch;
                match &study.runtime.fit {
                    Some((e, f)) if self.config.sampler_cache && *e == epoch => {
                        self.metrics.sampler_cache_hits.inc();
                        HistoryArm::Fit(Arc::clone(f))
                    }
                    _ => {
                        self.metrics.sampler_cache_misses.inc();
                        HistoryArm::Snap(epoch, study.obs_window(self.config.history_snapshot))
                    }
                }
            };
            (slot, numbers, sampler, arm, study.def.space.clone(), study.def.direction)
        };
        // Publish the created study's directory entry now that the
        // shard guard is gone (registry level 10 < shard level 20).
        self.publish_dir_entry(staged_dir);

        // --- fit OUTSIDE the lock (pure function of the history window,
        // no RNG — see the Sampler trait contract) ---
        let (fit, fit_epoch): (Arc<dyn FitState>, Option<u64>) = match arm {
            HistoryArm::None => (Arc::from(sampler.fit(&space, &[], direction)), None),
            HistoryArm::Fit(f) => (f, None),
            HistoryArm::Snap(epoch, obs_window) => {
                let t0 = Instant::now();
                let f: Arc<dyn FitState> = Arc::from(sampler.fit(&space, &obs_window, direction));
                let took = t0.elapsed();
                self.metrics.sampler_fit_seconds.observe(took.as_secs_f64());
                obs::stage(Stage::SamplerFit, took);
                (f, Some(epoch))
            }
        };

        // --- draw one suggestion per reserved number, each from its own
        // number-seeded RNG: byte-identical to r sequential asks ---
        let key_hash = fnv1a(key);
        let batch: Vec<(u64, Assignment)> = numbers
            .into_iter()
            .map(|number| {
                let mut rng = Rng::new(mix(mix(self.config.seed, key_hash), number));
                (number, sampler.suggest_fitted(&space, fit.as_ref(), number, &mut rng))
            })
            .collect();

        // --- critical section 2: insert the trials ---
        let replies = {
            // Bind-gate before shard lock (the engine-wide order is
            // gate → shard → fleet); held only for worker-bound asks.
            let _bind_gate = worker.map(|_| self.fleet_bind_gate.read_safe());
            let mut guard = self.lock_shard(shard_idx);
            let replies = self.insert_trials(
                &mut guard, shard_idx, slot, batch, now, node, worker, tenant, sites,
            )?;
            // Write the fit back under the same lock, and only if no
            // tell landed while we were fitting — a stale fit must
            // never shadow the newer history.
            if self.config.sampler_cache {
                if let Some(epoch) = fit_epoch {
                    let rt = &mut guard.studies[slot].runtime;
                    if rt.epoch == epoch {
                        rt.fit = Some((epoch, fit));
                    }
                }
            }
            replies
        };

        self.metrics.trials_created.add(r as u64);
        self.metrics.ask_total.add(r as u64);
        self.asks.fetch_add(r as u64, Ordering::Relaxed);
        self.maybe_compact();
        Ok(replies)
    }

    /// `ask` for a multi-objective study (paper §5 future work): same
    /// protocol, but the suggestion comes from NSGA-II over the study's
    /// objective *vectors*. Default sampler name "tpe" (the protocol
    /// default) is interpreted as "nsga2" for MO studies; random/grid/
    /// qmc work as-is; gp/cmaes are single-objective only.
    #[allow(clippy::too_many_arguments)]
    fn ask_mo(
        &self,
        def: StudyDef,
        node: Option<String>,
        now: f64,
        key: String,
        worker: Option<u64>,
        tenant: Option<&str>,
        site: Option<&str>,
    ) -> Result<AskReply, ApiError> {
        use super::samplers::nsga2::{MoObs, Nsga2Sampler};
        let directions = def.directions.clone().expect("mo study");
        enum MoWhich {
            Nsga2(Nsga2Sampler),
            Plain(Box<dyn super::samplers::Sampler>),
        }
        let which = match def.sampler.name.as_str() {
            "nsga2" | "tpe" => MoWhich::Nsga2(Nsga2Sampler::from_config(&def.sampler)),
            "random" | "grid" | "qmc" | "sobol" => {
                MoWhich::Plain(make_sampler(&def.sampler).map_err(ApiError::BadRequest)?)
            }
            other => {
                return Err(ApiError::BadRequest(format!(
                    "sampler '{other}' does not support multi-objective studies"
                )))
            }
        };
        let shard_idx = self.shard_of(&key);

        // --- critical section 1: find/create study, reserve the trial
        // number, snapshot history ---
        let mut staged_dir: Option<DirEntry> = None;
        let (slot, trial_number, mo_obs, space) = {
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            let slot =
                self.find_or_create_study(state, shard_idx, &def, now, &key, &mut staged_dir)?;
            let study = &mut state.studies[slot];
            let trial_number = study.reserve_number();
            let skip = study
                .mo_scored()
                .len()
                .saturating_sub(self.config.history_snapshot.max(1));
            let mo_obs: Vec<MoObs> = study
                .mo_scored()
                .into_iter()
                .skip(skip)
                .map(|(t, v)| MoObs { params: t.params.clone(), values: v.clone() })
                .collect();
            (slot, trial_number, mo_obs, study.def.space.clone())
        };
        // Publish the created study's directory entry now that the
        // shard guard is gone (registry level 10 < shard level 20).
        self.publish_dir_entry(staged_dir);

        // --- suggest outside the lock ---
        let key_hash = fnv1a(&key);
        let mut rng = Rng::new(mix(mix(self.config.seed, key_hash), trial_number));
        let params = match which {
            MoWhich::Nsga2(s) => s.suggest_mo(&space, &mo_obs, &directions, &mut rng),
            MoWhich::Plain(s) => {
                s.suggest(&space, &[], super::space::Direction::Minimize, trial_number, &mut rng)
            }
        };

        // --- critical section 2: insert the trial ---
        let reply = {
            let _bind_gate = worker.map(|_| self.fleet_bind_gate.read_safe());
            let mut guard = self.lock_shard(shard_idx);
            let sites: Vec<String> = site.map(|s| vec![s.to_string()]).unwrap_or_default();
            self.insert_trials(
                &mut guard,
                shard_idx,
                slot,
                vec![(trial_number, params)],
                now,
                node,
                worker,
                tenant,
                &sites,
            )?
            .remove(0)
        };
        self.metrics.trials_created.inc();
        self.metrics.ask_total.inc();
        self.asks.fetch_add(1, Ordering::Relaxed);
        self.maybe_compact();
        Ok(reply)
    }

    /// Critical section 2 of an ask (shared by single- and
    /// multi-objective paths): allocate the trial ids, insert the batch
    /// on its shard, persist every `trial_new` (with its `lease_bind`
    /// interleaved right after it, for worker-bound asks) in ONE
    /// group-commit roundtrip, and build the replies. Called with the
    /// shard lock held. The trial numbers were reserved in critical
    /// section 1 (they seeded the suggestions), so they are used as-is;
    /// if the persist below fails every number of the batch is consumed
    /// without a trial — gaps in the study's numbering, never
    /// duplicates. The record interleave `[trial_new_0, lease_bind_0,
    /// trial_new_1, …]` matches what the same trials committed one ask
    /// at a time would write, so recovery replay cannot tell a batch
    /// from a sequential burst.
    #[allow(clippy::too_many_arguments)]
    fn insert_trials(
        &self,
        state: &mut ShardState,
        shard_idx: usize,
        slot: usize,
        batch: Vec<(u64, Assignment)>,
        now: f64,
        node: Option<String>,
        worker: Option<u64>,
        tenant: Option<&str>,
        sites: &[String],
    ) -> Result<Vec<AskReply>, ApiError> {
        let study_id = state.studies[slot].id;
        let study_key = state.studies[slot].key.clone();
        let mut trials: Vec<Trial> = Vec::with_capacity(batch.len());
        let mut records: Vec<Record> = Vec::with_capacity(batch.len() * 2);
        for (i, (trial_number, params)) in batch.into_iter().enumerate() {
            let trial_id = self.next_trial_id.fetch_add(1, Ordering::Relaxed);
            let trial = Trial::new(trial_id, trial_number, params, now, node.clone());
            let ev = {
                let mut o = Value::obj();
                o.set("study_id", study_id).set("trial", trial.to_json());
                Value::Obj(o)
            };
            // Persist first: a failed append returns 500 with no
            // in-memory trace, so memory never diverges from the log. A
            // worker-bound ask journals each lease in the same commit
            // batch (one fsync); the caller holds the bind gate across
            // this whole critical section so a concurrent fleet segment
            // cut can never cover a bind it did not snapshot.
            records.push(Record::new("trial_new", ev).with_shard(shard_idx as u32));
            if let Some(wid) = worker {
                // The admission keys (the site `admit` counted, the
                // tenant) ride the record so recovery rebuilds
                // per-site/per-tenant counters exactly as live.
                let site = sites.get(i).map(String::as_str).unwrap_or("");
                records.push(
                    Record::new(
                        "lease_bind",
                        Self::lease_bind_payload(trial_id, wid, &study_key, site, tenant, now),
                    )
                    .with_shard(FLEET_SHARD),
                );
            }
            trials.push(trial);
        }
        self.persist_many(records)?;
        let start_slot = state.studies[slot].trials.len();
        let mut replies = Vec::with_capacity(trials.len());
        for (i, trial) in trials.into_iter().enumerate() {
            let trial_id = trial.id;
            let trial_number = trial.number;
            let params = assignment_to_json(&trial.params);
            let trial_idx = state.studies[slot].trials.len();
            state.studies[slot].trials.push(trial);
            state.trial_index.insert(trial_id, (slot, trial_idx));
            state.last_seen.insert(trial_id, now);
            self.router.insert(trial_id, shard_idx);
            if let Some(wid) = worker {
                // Shard lock (level 20) is held; the fleet lock (25) is
                // above it in the canonical order, so this nesting is legal.
                let site = sites.get(i).map(String::as_str).unwrap_or("");
                self.fleet.lock().bind(trial_id, wid, &study_key, site, tenant, now);
            }
            replies.push(AskReply {
                trial_id,
                trial_number,
                study_id,
                study_key: study_key.clone(),
                params,
                requeued: false,
            });
        }
        // One view publication for the whole acknowledged batch, still
        // under the shard lock: a reader never sees a torn mid-batch
        // trial set.
        self.views.on_trials_inserted(&state.studies[slot], start_slot);
        self.shard_metrics_update(shard_idx, state);
        Ok(replies)
    }

    /// Payload of a `lease_bind` record. Carries the admission keys
    /// (site, tenant) so recovery rebuilds quota counters exactly.
    fn lease_bind_payload(
        trial_id: u64,
        worker_id: u64,
        study_key: &str,
        site: &str,
        tenant: Option<&str>,
        now: f64,
    ) -> Value {
        let mut o = Value::obj();
        o.set("trial_id", trial_id)
            .set("worker_id", worker_id)
            .set("study_key", study_key)
            .set("site", site)
            .set("tenant", tenant.map(str::to_string))
            .set("at", now);
        Value::Obj(o)
    }

    /// Hand a requeued trial of `study_key` (one whose worker was lost)
    /// to `worker`, if any is waiting. The trial keeps its original id,
    /// number and parameters — the suggestion stream is untouched. The
    /// caller has already admitted the worker; the admission slot
    /// becomes the new lease.
    ///
    /// With site affinity enabled, a worker on a site whose preemption
    /// rate is above the fleet mean is *deferred*: it gets a fresh trial
    /// instead of the queue head, leaving the old trial for a healthier
    /// site — until the head has waited a full fairness horizon, after
    /// which any site may take it (affinity is a preference, never a
    /// starvation). Because the handed-out trial keeps its identity and
    /// fresh trials draw from the untouched number reservation, the
    /// suggestion stream is byte-identical with affinity on or off.
    fn assign_requeued(
        &self,
        study_key: &str,
        worker: u64,
        tenant: Option<&str>,
        site: &str,
        now: f64,
    ) -> Result<Option<AskReply>, ApiError> {
        if self.fleet.config.policy.site_affinity {
            let fl = self.fleet.lock();
            if !fl.sched.site_preferred(site) {
                let grace = self.fleet.config.policy.fairness_horizon.max(0.0);
                if let Some(wait) = fl.leases.head_wait(study_key, now) {
                    if wait < grace {
                        self.metrics.fleet_affinity_deferrals.inc();
                        return Ok(None);
                    }
                }
            }
        }
        loop {
            // The bind gate covers the whole pop → persist → bind (or
            // push-back) window: a fleet segment cut (the gate's write
            // side) can therefore never observe the trial mid-handout —
            // it sees it either still queued or already leased, and the
            // records this section appends sort after the cut.
            let _bind_gate = self.fleet_bind_gate.read_safe();
            let Some(trial_id) = self.fleet.lock().leases.pop_front(study_key) else {
                return Ok(None);
            };
            let Some(shard_idx) = self.router.get(trial_id) else {
                // Phantom queue entry (torn log): drop every trace.
                self.fleet.lock().finish_trial(trial_id, study_key);
                continue;
            };
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            let Some(&(si, ti)) = state.trial_index.get(&trial_id) else {
                drop(guard);
                self.fleet.lock().finish_trial(trial_id, study_key);
                continue;
            };
            if state.studies[si].trials[ti].state != TrialState::Running {
                // A straggler tell from the lost worker finished it
                // while it sat in the queue — drop it and keep looking.
                self.fleet.lock().finish_trial(trial_id, study_key);
                continue;
            }
            let record = Record::new(
                "lease_bind",
                Self::lease_bind_payload(trial_id, worker, study_key, site, tenant, now),
            )
            .with_shard(FLEET_SHARD);
            if let Err(e) = self.persist(record) {
                // Not handed out: back to the head of the queue.
                self.fleet.lock().leases.push_front(study_key, trial_id, now);
                return Err(e);
            }
            state.last_seen.insert(trial_id, now);
            self.fleet.lock().bind(trial_id, worker, study_key, site, tenant, now);
            let study = &state.studies[si];
            let trial = &study.trials[ti];
            let reply = AskReply {
                trial_id,
                trial_number: trial.number,
                study_id: study.id,
                study_key: study.key.clone(),
                params: assignment_to_json(&trial.params),
                requeued: true,
            };
            self.metrics.fleet_trials_reassigned.inc();
            self.metrics.ask_total.inc();
            self.asks.fetch_add(1, Ordering::Relaxed);
            return Ok(Some(reply));
        }
    }

    /// `tell` with an objective vector (multi-objective studies).
    /// Returns `(study_id, on_pareto_front)`.
    pub fn tell_values(&self, trial_id: u64, values: Vec<f64>) -> Result<(u64, bool), ApiError> {
        self.check_writable()?;
        let now = self.now();
        let shard_idx = self.route(trial_id)?;
        let result = {
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            let (si, ti) = *state
                .trial_index
                .get(&trial_id)
                .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
            obs::set_study(state.studies[si].id);
            let Some(directions) = state.studies[si].def.directions.clone() else {
                return Err(ApiError::BadRequest(
                    "'values' array sent to a single-objective study".into(),
                ));
            };
            if values.len() != directions.len() {
                return Err(ApiError::BadRequest(format!(
                    "expected {} objective values, got {}",
                    directions.len(),
                    values.len()
                )));
            }
            // Validate, persist, then apply (see `tell`).
            state.studies[si].trials[ti]
                .validate_transition("tell")
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id)
                    .set(
                        "values",
                        Value::Arr(values.iter().map(|&v| Value::Num(v)).collect()),
                    )
                    .set("at", now);
                Value::Obj(o)
            };
            self.persist(Record::new("trial_tell_mo", ev).with_shard(shard_idx as u32))?;
            state.studies[si].trials[ti]
                .complete_mo(values, now)
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            state.last_seen.remove(&trial_id);
            if self.fleet_active.load(Ordering::Relaxed) {
                self.fleet.lock().finish_trial(trial_id, &state.studies[si].key);
            }
            self.views.on_trial_updated(&state.studies[si], ti, Some(EventKind::Completed));
            self.shard_metrics_update(shard_idx, state);
            let on_front = state.studies[si]
                .pareto()
                .iter()
                .any(|t| t.id == trial_id);
            (state.studies[si].id, on_front)
        };
        self.metrics.tell_total.inc();
        self.metrics.trials_completed.inc();
        self.maybe_compact();
        Ok(result)
    }

    /// Pareto front of a multi-objective study (dashboard/client API).
    pub fn pareto_json(&self, study_id: u64) -> Option<Value> {
        self.with_study(study_id, |study| {
            Value::Arr(study.pareto().into_iter().map(|t| t.to_json()).collect())
        })
    }

    /// `tell`: finalize a trial with its objective value.
    /// Returns `(study_id, is_best)`.
    pub fn tell(&self, trial_id: u64, value: f64) -> Result<(u64, bool), ApiError> {
        self.check_writable()?;
        let now = self.now();
        let shard_idx = self.route(trial_id)?;
        let result = {
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            let (si, ti) = *state
                .trial_index
                .get(&trial_id)
                .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
            obs::set_study(state.studies[si].id);
            let direction = state.studies[si].def.direction;
            let prev_best = state.studies[si].best().and_then(|t| t.value);
            // Validate the transition, persist, then apply: a failed
            // append returns 500 with the trial still Running, so the
            // client's retry can succeed instead of hitting 409.
            state.studies[si].trials[ti]
                .validate_transition("tell")
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id).set("value", value).set("at", now);
                Value::Obj(o)
            };
            self.persist(Record::new("trial_tell", ev).with_shard(shard_idx as u32))?;
            state.studies[si].trials[ti]
                .complete(value, now)
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            // The scored history changed: bump the study's tell-epoch so
            // the next ask refits instead of reusing the cached fit.
            state.studies[si].note_scored(ti, self.config.history_snapshot);
            state.last_seen.remove(&trial_id);
            if self.fleet_active.load(Ordering::Relaxed) {
                self.fleet.lock().finish_trial(trial_id, &state.studies[si].key);
            }
            self.views.on_trial_updated(&state.studies[si], ti, Some(EventKind::Completed));
            self.shard_metrics_update(shard_idx, state);
            let is_best = match prev_best {
                None => true,
                Some(b) => direction.better(value, b),
            };
            (state.studies[si].id, is_best)
        };
        self.metrics.tell_total.inc();
        self.metrics.trials_completed.inc();
        self.maybe_compact();
        Ok(result)
    }

    /// `should_prune`: record an intermediate value; returns whether the
    /// client should abort the trial. A `true` response transitions the
    /// trial to Pruned server-side (the client contract is to stop).
    pub fn should_prune(&self, trial_id: u64, step: u64, value: f64) -> Result<bool, ApiError> {
        self.check_writable()?;
        let now = self.now();
        let shard_idx = self.route(trial_id)?;
        let prune = {
            let mut guard = self.lock_shard(shard_idx);
            let state = &mut *guard;
            let (si, ti) = *state
                .trial_index
                .get(&trial_id)
                .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;

            obs::set_study(state.studies[si].id);
            // Validate, persist, then apply (see `tell`). `report` runs
            // the same validation internally, so the two cannot drift.
            state.studies[si].trials[ti]
                .validate_report(step)
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id).set("step", step).set("value", value);
                Value::Obj(o)
            };
            self.persist(Record::new("trial_report", ev).with_shard(shard_idx as u32))?;
            state.studies[si].trials[ti]
                .report(step, value)
                .map_err(|e| ApiError::Conflict(e.to_string()))?;
            state.last_seen.insert(trial_id, now);
            self.metrics.should_prune_total.inc();

            let study = &state.studies[si];
            let prune = match &study.def.pruner {
                None => false,
                Some(cfg) => {
                    let pruner = make_pruner(cfg).map_err(ApiError::BadRequest)?;
                    let trial = &study.trials[ti];
                    let history: Vec<&Trial> = study
                        .trials
                        .iter()
                        .filter(|t| t.id != trial_id)
                        .collect();
                    pruner.should_prune(trial, step, value, &history, study.def.direction)
                }
            };
            if prune {
                // The trial is Running (the report above succeeded and
                // the lock is held), so persist-then-apply cannot 409.
                let ev = {
                    let mut o = Value::obj();
                    o.set("trial_id", trial_id).set("at", now);
                    Value::Obj(o)
                };
                self.persist(Record::new("trial_prune", ev).with_shard(shard_idx as u32))?;
                state.studies[si].trials[ti]
                    .prune(now)
                    .map_err(|e| ApiError::Conflict(e.to_string()))?;
                // A pruned trial scores at its last intermediate (the
                // report above), so the scored history changed too.
                state.studies[si].note_scored(ti, self.config.history_snapshot);
                state.last_seen.remove(&trial_id);
                if self.fleet_active.load(Ordering::Relaxed) {
                    self.fleet.lock().finish_trial(trial_id, &state.studies[si].key);
                }
                self.metrics.prune_decisions.inc();
                self.metrics.trials_pruned.inc();
            }
            self.views.on_trial_updated(
                &state.studies[si],
                ti,
                if prune { Some(EventKind::Pruned) } else { None },
            );
            self.shard_metrics_update(shard_idx, state);
            prune
        };
        self.maybe_compact();
        Ok(prune)
    }

    /// Client-reported failure (e.g. OOM) — frees the trial slot.
    pub fn fail(&self, trial_id: u64) -> Result<(), ApiError> {
        self.check_writable()?;
        let now = self.now();
        let shard_idx = self.route(trial_id)?;
        let mut guard = self.lock_shard(shard_idx);
        let state = &mut *guard;
        let (si, ti) = *state
            .trial_index
            .get(&trial_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown trial {trial_id}")))?;
        obs::set_study(state.studies[si].id);
        // Validate, persist, then apply (see `tell`).
        state.studies[si].trials[ti]
            .validate_transition("fail")
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        let ev = {
            let mut o = Value::obj();
            o.set("trial_id", trial_id).set("at", now);
            Value::Obj(o)
        };
        self.persist(Record::new("trial_fail", ev).with_shard(shard_idx as u32))?;
        state.studies[si].trials[ti]
            .fail(now)
            .map_err(|e| ApiError::Conflict(e.to_string()))?;
        state.last_seen.remove(&trial_id);
        if self.fleet_active.load(Ordering::Relaxed) {
            self.fleet.lock().finish_trial(trial_id, &state.studies[si].key);
        }
        self.views.on_trial_updated(&state.studies[si], ti, Some(EventKind::Failed));
        self.shard_metrics_update(shard_idx, state);
        self.metrics.trials_failed.inc();
        Ok(())
    }

    /// Reap running trials whose node has been silent past the deadline
    /// (called periodically by the server loop). Shards are swept one at
    /// a time, so reaping never blocks the whole engine.
    ///
    /// Leased trials are exempt: their fate belongs to the worker's
    /// heartbeat deadline ([`Engine::expire_leases`] requeues them
    /// deterministically instead of failing them). The exemption only
    /// applies while lease expiry is on — with `--lease-timeout 0` a
    /// vanished worker's leases would otherwise never be released.
    /// *Requeued* trials are not exempt: a requeue refreshes
    /// `last_seen`, so a queued trial gets one full `reap_after` window
    /// to find a new worker, after which the reaper fails it (and
    /// scrubs its fleet entries) — the pre-fleet guarantee that every
    /// silent Running trial is eventually bounded by `reap_after`
    /// still holds.
    pub fn reap_stale(&self) -> usize {
        if !self.is_writable() {
            return 0;
        }
        let Some(deadline) = self.config.reap_after else { return 0 };
        let now = self.now();
        // Collected before any shard lock is taken (fleet is a leaf
        // lock; the set may be momentarily stale, which only delays a
        // reap by one sweep).
        let leased: HashSet<u64> = if self.fleet_active.load(Ordering::Relaxed)
            && self.config.lease_timeout.is_some()
        {
            self.fleet.lock().leases.leased_ids().into_iter().collect()
        } else {
            HashSet::new()
        };
        let mut reaped = 0;
        for (shard_idx, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.state.lock_safe();
            let state = &mut *guard;
            let stale: Vec<u64> = state
                .last_seen
                .iter()
                .filter(|(_, &t)| now - t > deadline)
                .map(|(&id, _)| id)
                .filter(|id| !leased.contains(id))
                .collect();
            // Build every trial_fail record first and commit them in one
            // group-commit roundtrip: a vanished site can expire
            // thousands of trials at once, and per-trial roundtrips
            // would serialize that many fsync waits under the shard
            // lock.
            let mut to_fail: Vec<u64> = Vec::new();
            let mut records: Vec<Record> = Vec::new();
            for &id in &stale {
                if let Some(&(si, ti)) = state.trial_index.get(&id) {
                    if state.studies[si].trials[ti].validate_transition("fail").is_ok() {
                        let ev = {
                            let mut o = Value::obj();
                            o.set("trial_id", id).set("at", now);
                            Value::Obj(o)
                        };
                        records.push(Record::new("trial_fail", ev).with_shard(shard_idx as u32));
                        to_fail.push(id);
                    }
                }
            }
            if self.persist_many(records).is_ok() {
                for id in to_fail {
                    if let Some(&(si, ti)) = state.trial_index.get(&id) {
                        let _ = state.studies[si].trials[ti].fail(now);
                        // A reaped trial may still carry fleet state
                        // (a lease under --lease-timeout 0): scrub it
                        // so quota slots and queues cannot leak.
                        if self.fleet_active.load(Ordering::Relaxed) {
                            self.fleet.lock().finish_trial(id, &state.studies[si].key);
                        }
                        self.views.on_trial_updated(
                            &state.studies[si],
                            ti,
                            Some(EventKind::Failed),
                        );
                        self.metrics.trials_failed.inc();
                        reaped += 1;
                    }
                }
                for id in stale {
                    state.last_seen.remove(&id);
                }
            }
            // Gauge only: an idle sweep is not a shard mutation.
            if let Some(sm) = self.metrics.shards.get(shard_idx) {
                sm.tracked_running.set(state.last_seen.len() as f64);
            }
        }
        reaped
    }

    // ------------------------------------------------------------------
    // Fleet APIs (worker registry, heartbeat leases, lease expiry)
    // ------------------------------------------------------------------

    /// Register a fleet worker (`POST /api/workers/register`). Returns
    /// `(worker_id, lease_timeout)`; the worker must heartbeat within
    /// the lease window or its trials are requeued.
    pub fn register_worker(
        &self,
        name: &str,
        site: &str,
        gpu: &str,
    ) -> Result<(u64, Option<f64>), ApiError> {
        self.check_writable()?;
        let now = self.now();
        let ttl = self.fleet.ttl();
        let mut fl = self.fleet.lock();
        let id = fl.registry.next_id();
        let ev = {
            let mut o = Value::obj();
            o.set("id", id)
                .set("name", name)
                .set("site", site)
                .set("gpu", gpu)
                .set("at", now);
            Value::Obj(o)
        };
        // Persist-then-apply, like every other mutation: the fleet lock
        // is held across the append so the fleet segment cut is exact.
        self.persist(Record::new("worker_register", ev).with_shard(FLEET_SHARD))?;
        fl.registry.apply_register(id, name, site, gpu, now, now + ttl);
        self.fleet_active.store(true, Ordering::Relaxed);
        self.metrics.fleet_workers_registered.inc();
        Ok((id, self.config.lease_timeout))
    }

    /// Renew a worker's lease (`POST /api/workers/heartbeat`). Returns
    /// the number of trials the renewed lease covers. 404 for unknown
    /// workers; 409 once the worker has been marked lost (its trials
    /// are gone to other workers — it must re-register).
    pub fn worker_heartbeat(&self, worker_id: u64) -> Result<usize, ApiError> {
        self.check_writable()?;
        let now = self.now();
        let ttl = self.fleet.ttl();
        let mut fl = self.fleet.lock();
        if fl.registry.get(worker_id).is_none() {
            return Err(ApiError::NotFound(format!("unknown worker {worker_id}")));
        }
        match fl.registry.heartbeat(worker_id, now, ttl) {
            Ok(w) => Ok(w.leases.len()),
            Err(msg) => Err(ApiError::Conflict(msg)),
        }
    }

    /// Graceful worker shutdown (`POST /api/workers/deregister`): the
    /// worker's running trials are requeued immediately — no lease
    /// expiry wait — and the worker id is retired. Returns how many
    /// trials were handed back.
    pub fn deregister_worker(&self, worker_id: u64) -> Result<usize, ApiError> {
        self.check_writable()?;
        let now = self.now();
        let trials: Vec<u64> = {
            let mut fl = self.fleet.lock();
            let Some(w) = fl.registry.get(worker_id) else {
                return Err(ApiError::NotFound(format!("unknown worker {worker_id}")));
            };
            if w.state != crate::fleet::WorkerState::Alive {
                // Mirror heartbeat: a lost worker's trials are already
                // gone to others; there is nothing left to hand back.
                return Err(ApiError::Conflict(format!(
                    "worker {worker_id} is {}: nothing to deregister",
                    w.state.as_str()
                )));
            }
            let mut trials: Vec<u64> = w.leases.iter().copied().collect();
            trials.sort_unstable();
            let ev = {
                let mut o = Value::obj();
                o.set("worker_id", worker_id).set("at", now);
                Value::Obj(o)
            };
            self.persist(Record::new("worker_deregister", ev).with_shard(FLEET_SHARD))?;
            fl.registry.mark_deregistered(worker_id);
            trials
        };
        let mut handed_back = 0;
        for tid in trials {
            // Only actual requeues count as "handed back" — a trial
            // whose budget is spent is failed, not resumed elsewhere.
            if self.requeue_or_fail(tid, worker_id, now) == Some(true) {
                handed_back += 1;
            }
        }
        Ok(handed_back)
    }

    /// Expire worker leases whose heartbeat deadline has passed: mark
    /// the worker lost and requeue (or, once the requeue budget is
    /// spent, fail) each of its running trials — durably, one record
    /// per decision, so a crash mid-expiry resumes exactly where it
    /// stopped. Called periodically by the server loop; the replacement
    /// for `reap_stale` on worker-bound trials. Returns the number of
    /// trials requeued or failed.
    pub fn expire_leases(&self) -> usize {
        // With expiry disabled (`--lease-timeout 0`) deadlines sit at
        // infinity and never pass, but the sweep still runs: it heals
        // orphaned leases of lost/deregistered workers (a crash between
        // `worker_lost` and the per-trial requeues) and hosts the fleet
        // GC. Only a fleet that was never used skips it entirely — but
        // the worker-less ask-rate ledger is swept regardless, because
        // purely legacy deployments never activate the fleet at all.
        if !self.is_writable() {
            return 0;
        }
        let now = self.now();
        self.fleet.gc_ask_rates(now);
        if !self.fleet_active.load(Ordering::Relaxed) {
            return 0;
        }
        let expired = self.fleet.lock().expired_workers(now);
        let mut handled = 0;
        for (wid, was_alive, trials) in expired {
            {
                let mut fl = self.fleet.lock();
                // Re-check under the lock: a heartbeat may have revived
                // the worker between collection and processing.
                if !fl.registry.is_expiry_candidate(wid, now) {
                    continue;
                }
                if was_alive {
                    let ev = {
                        let mut o = Value::obj();
                        o.set("worker_id", wid).set("at", now);
                        Value::Obj(o)
                    };
                    if self
                        .persist(Record::new("worker_lost", ev).with_shard(FLEET_SHARD))
                        .is_err()
                    {
                        continue;
                    }
                    fl.registry.mark_lost(wid, now);
                    self.metrics.fleet_workers_lost.inc();
                }
            }
            for tid in trials {
                if self.requeue_or_fail(tid, wid, now).is_some() {
                    handled += 1;
                }
            }
        }
        // Bound the fleet tables: spot-heavy fleets register a fresh id
        // per respawn and sites are client-supplied strings — dead
        // workers and long-idle sites would otherwise accumulate
        // forever in memory, the fleet segment and this very sweep.
        // Both retentions are operator knobs (`--dead-worker-keep`,
        // `--site-idle-retention`); waiting marks expire on the much
        // shorter fairness horizon, the same clock admission uses.
        {
            let cfg = &self.fleet.config;
            let mut fl = self.fleet.lock();
            fl.registry.gc_dead(cfg.dead_worker_keep);
            fl.sched
                .gc_idle(now, cfg.site_idle_retention, cfg.policy.fairness_horizon.max(1.0));
        }
        handled
    }

    /// One trial of a lost/deregistered worker: requeue it when budget
    /// remains (`Some(true)`), fail it durably otherwise
    /// (`Some(false)`). `None` = nothing to do — the lease was already
    /// gone: a straggler tell beat us, or a previous partially-crashed
    /// expiry already handled it.
    fn requeue_or_fail(&self, trial_id: u64, expected_worker: u64, now: f64) -> Option<bool> {
        let shard_idx = self.route(trial_id).ok()?;
        let mut guard = self.lock_shard(shard_idx);
        let state = &mut *guard;
        let Some(&(si, ti)) = state.trial_index.get(&trial_id) else { return None };
        if state.studies[si].trials[ti].validate_transition("fail").is_err() {
            // Already terminal: the lease (if any) is stale bookkeeping.
            let study_key = state.studies[si].key.clone();
            self.fleet.lock().finish_trial(trial_id, &study_key);
            return None;
        }
        let study_key = state.studies[si].key.clone();
        let mut fl = self.fleet.lock();
        let info = fl.leases.get(trial_id)?;
        if info.worker != expected_worker {
            return None; // re-homed already
        }
        let lease_site = info.site.clone();
        if fl.leases.requeues(trial_id) < self.config.requeue_max {
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id)
                    .set("study_key", study_key.as_str())
                    .set("at", now);
                Value::Obj(o)
            };
            if self
                .persist(Record::new("trial_requeue", ev).with_shard(FLEET_SHARD))
                .is_err()
            {
                return None;
            }
            let requeued = fl.requeue(trial_id, expected_worker, now);
            debug_assert!(requeued, "lease checked under this lock");
            // Give the queued trial a fresh reap window: it is waiting
            // for a worker, not abandoned.
            state.last_seen.insert(trial_id, now);
            self.metrics.fleet_trials_requeued.inc();
            Some(true)
        } else {
            // Budget spent: fail the trial for good (shard-stamped
            // record — this *is* a trial state transition). The loss is
            // journaled alongside as a fleet `site_loss` record so the
            // persisted health ledger replays it: with `--requeue-max 0`
            // this is the *only* loss signal affinity ever sees, and it
            // must survive a restart like the requeue-path losses do.
            let ev = {
                let mut o = Value::obj();
                o.set("trial_id", trial_id).set("at", now);
                Value::Obj(o)
            };
            let loss = {
                let mut o = Value::obj();
                o.set("site", lease_site.as_str()).set("at", now);
                Value::Obj(o)
            };
            if self
                .persist_many(vec![
                    Record::new("trial_fail", ev).with_shard(shard_idx as u32),
                    Record::new("site_loss", loss).with_shard(FLEET_SHARD),
                ])
                .is_err()
            {
                return None;
            }
            let _ = state.studies[si].trials[ti].fail(now);
            state.last_seen.remove(&trial_id);
            // The budget-exhausting loss still counts against the
            // site's health ledger (with `--requeue-max 0` it is the
            // *only* loss signal affinity would ever see).
            fl.sched.note_loss(&lease_site);
            fl.finish_trial(trial_id, &study_key);
            drop(fl);
            self.views.on_trial_updated(&state.studies[si], ti, Some(EventKind::Failed));
            self.shard_metrics_update(shard_idx, state);
            self.metrics.trials_failed.inc();
            Some(false)
        }
    }

    /// Fleet worker listing (`GET /api/workers`).
    pub fn workers_json(&self) -> Value {
        self.fleet.lock().registry.to_json()
    }

    /// Fleet tables (tests and the stats/metrics paths).
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    // ------------------------------------------------------------------
    // Read APIs (dashboard / web data)
    // ------------------------------------------------------------------

    /// Run `f` on the study with `study_id`, wherever it lives. The
    /// directory guard is released before the shard lock is taken (leaf
    /// lock discipline).
    fn with_study<T>(&self, study_id: u64, f: impl FnOnce(&Study) -> T) -> Option<T> {
        let entry = self.directory.read_safe().lookup(study_id)?;
        let guard = self.lock_shard(entry.shard);
        Some(f(&guard.studies[entry.slot]))
    }

    /// Summaries of all studies, in id (creation) order.
    pub fn studies_json(&self) -> Value {
        let entries = self.directory.read_safe().sorted();
        let mut out: Vec<Value> = Vec::with_capacity(entries.len());
        let mut i = 0;
        while i < entries.len() {
            // One shard lock per run of same-shard entries.
            let shard = entries[i].shard;
            let guard = self.lock_shard(shard);
            while i < entries.len() && entries[i].shard == shard {
                out.push(guard.studies[entries[i].slot].summary_json());
                i += 1;
            }
        }
        Value::Arr(out)
    }

    /// One study's summary.
    pub fn study_json(&self, study_id: u64) -> Option<Value> {
        self.with_study(study_id, |s| s.summary_json())
    }

    /// A study's full trial list.
    pub fn trials_json(&self, study_id: u64) -> Option<Value> {
        self.with_study(study_id, |s| {
            Value::Arr(s.trials.iter().map(|t| t.to_json()).collect())
        })
    }

    /// Loss-curve series for the dashboard plots (paper: Chartist plots
    /// of "the evolution of the loss reported by different studies and
    /// trials").
    pub fn series_json(&self, study_id: u64) -> Option<Value> {
        self.with_study(study_id, |study| {
            let mut arr = Vec::new();
            for t in &study.trials {
                let mut o = Value::obj();
                o.set("trial", t.id)
                    .set("state", t.state.as_str())
                    .set(
                        "points",
                        Value::Arr(
                            t.intermediate
                                .iter()
                                .map(|(s, v)| {
                                    Value::Arr(vec![Value::Num(*s as f64), Value::Num(*v)])
                                })
                                .collect(),
                        ),
                    )
                    .set("final", t.value);
                arr.push(Value::Obj(o));
            }
            Value::Arr(arr)
        })
    }

    /// Best-so-far curve of a study: (trial number, best value after it).
    pub fn best_curve(&self, study_id: u64) -> Option<Vec<(u64, f64)>> {
        self.with_study(study_id, |study| {
            let mut best: Option<f64> = None;
            let mut curve = Vec::new();
            for t in &study.trials {
                if let (TrialState::Completed, Some(v)) = (t.state, t.value) {
                    best = Some(match best {
                        None => v,
                        Some(b) if study.def.direction.better(v, b) => v,
                        Some(b) => b,
                    });
                    curve.push((t.number, best.unwrap()));
                }
            }
            curve
        })
    }

    /// Number of studies.
    pub fn n_studies(&self) -> usize {
        self.directory.read_safe().len()
    }

    /// Look up a study id by definition key.
    pub fn study_id_by_key(&self, key: &str) -> Option<u64> {
        let guard = self.lock_shard(self.shard_of(key));
        guard.by_key.get(key).map(|&slot| guard.studies[slot].id)
    }

    /// Live `last_seen` entries across all shards — the set of running
    /// trials tracked for reaping. Returns to 0 when every trial has
    /// reached a terminal state (leak regression surface).
    pub fn tracked_running(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.state.lock_safe().last_seen.len())
            .sum()
    }

    /// Engine-level statistics (the `/api/stats` endpoint).
    pub fn stats_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("shards", self.shards.len())
            .set("studies", self.n_studies())
            .set("asks", self.asks.load(Ordering::Relaxed))
            .set("tracked_running", self.tracked_running())
            .set("wal_records", self.wal_records.load(Ordering::Relaxed))
            .set("durable", self.wal.get().is_some())
            .set("uptime_seconds", self.start.elapsed().as_secs_f64());
        {
            let mut b = Value::obj();
            b.set("version", crate::VERSION)
                .set("git_hash", crate::GIT_HASH.unwrap_or("unknown"));
            o.set("build", Value::Obj(b));
        }
        // Tracing subsystem counters + slow-trace exemplar ids.
        o.set("trace", self.tracer.stats_json());
        if let Some(wal) = self.wal.get() {
            let (batches, records, last, max) = wal.stats().snapshot();
            let mut w = Value::obj();
            w.set("batches", batches)
                .set("records", records)
                .set("last_batch", last)
                .set("max_batch", max)
                .set(
                    "failed_batches",
                    wal.stats().failed_batches.load(Ordering::Relaxed),
                )
                .set(
                    "batch_limit",
                    wal.stats().batch_limit.load(Ordering::Relaxed),
                )
                .set("adaptive", self.config.wal_batch_adaptive)
                .set(
                    "segments_reused",
                    wal.stats().segments_reused.load(Ordering::Relaxed),
                )
                .set("recent_batches", wal.ledger_json());
            o.set("wal_commit", Value::Obj(w));
        }
        // Sampler hot path: fit-cache effectiveness and batch sizes.
        {
            let mut s = Value::obj();
            s.set("cache", self.config.sampler_cache)
                .set("cache_hits", self.metrics.sampler_cache_hits.get())
                .set("cache_misses", self.metrics.sampler_cache_misses.get())
                .set("fits", self.metrics.sampler_fit_seconds.count())
                .set("fit_mean_seconds", self.metrics.sampler_fit_seconds.mean())
                .set("ask_batches", self.metrics.ask_batch_size.count())
                .set("ask_batch_mean", self.metrics.ask_batch_size.mean());
            o.set("sampler", Value::Obj(s));
        }
        // Replication block: role, cursor, and lag (follower), plus
        // the log window being served (primary).
        {
            let next = self.repl_next.load(Ordering::Relaxed);
            let primary_next = self.repl_primary_next.load(Ordering::Relaxed);
            let mut r = Value::obj();
            r.set("role", if self.config.follower { "follower" } else { "primary" })
                .set("writable", self.is_writable())
                .set("next", next)
                .set("primary_next", primary_next)
                .set("lag_seq", primary_next.saturating_sub(next));
            if let Some(p) = &self.config.primary_url {
                r.set("primary_url", p.as_str());
            }
            if let Some(src) = self.repl_source.get() {
                r.set("log_floor", src.floor())
                    .set("log_next", src.next_seq())
                    .set("log_buffered", src.buffered());
            }
            o.set("repl", Value::Obj(r));
        }
        // Fleet block: worker registry + lease + scheduler state.
        o.set("fleet", self.fleet.lock().stats_json(&self.fleet.config));
        // What the last recovery pass observed (zeros in-memory) — the
        // torn-tail surface operators check after a crashy restart.
        let rec = self.recovery;
        let mut r = Value::obj();
        r.set("recovered_records", rec.recovered_records)
            .set("filtered_records", rec.filtered_records)
            .set("truncated_records", rec.truncated_records)
            .set("truncated_bytes", rec.truncated_bytes)
            .set("segments", rec.segments)
            .set("orphan_records", rec.orphan_records)
            .set("seq_order_violations", rec.seq_order_violations);
        o.set("wal_recovery", Value::Obj(r));
        Value::Obj(o)
    }

    /// Incremental compaction: rotate the log, then cut one snapshot
    /// segment per shard — pausing only the shard being cut — and commit
    /// the segment set with a manifest. Never takes two shard locks at
    /// once; every other shard keeps serving mutations throughout.
    ///
    /// Why the per-shard cut is consistent: a shard's mutations hold its
    /// lock across their WAL append, so while we hold that lock here no
    /// record of the shard can be in flight; the writer thread stamps
    /// the segment with the shard's exact high-water `seq`. Records a
    /// shard commits *after* its cut are replayed on top of its segment
    /// at recovery — the manifest's per-shard `next_seq` filter makes
    /// the split exact.
    pub fn compact(&self) -> Result<(), ApiError> {
        let Some(wal) = self.wal.get() else { return Ok(()) };
        // One compaction at a time: the begin/cut/finish phases of two
        // drivers must not interleave on the writer thread.
        let _serial = self.compact_lock.lock_safe();
        let mut cut_resets: Vec<(usize, u64)> = Vec::new();
        let mut fleet_cut: Option<u64> = None;
        match self.compact_phases(wal, &mut cut_resets, &mut fleet_cut) {
            Ok(carried) => {
                // Records appended during the compaction live in the new
                // epoch's log and still count against the next compaction
                // threshold. `carried` races with concurrent `persist`
                // increments, so the counter can drift by the handful of
                // in-flight mutations — acceptable for a compaction
                // *policy* input, never consulted for correctness.
                self.wal_records.store(carried, Ordering::Relaxed);
                self.metrics.wal_records.set(carried as f64);
                Ok(())
            }
            Err(e) => {
                // The manifest never committed, so the segments cut so
                // far are orphans: the records they covered must count
                // as dirty again, or a later clean-shard reuse of the
                // *previous* manifest's segment would drop them.
                for (idx, captured) in cut_resets {
                    self.shard_dirty[idx].fetch_add(captured, Ordering::Relaxed);
                }
                if let Some(captured) = fleet_cut {
                    self.fleet_dirty.fetch_add(captured, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// The rotate → spec per shard → cut segments on the side pool →
    /// commit sequence of one compaction. Each cut spec records the
    /// dirty count it consumed in `cut_resets` / `fleet_cut` so
    /// [`Engine::compact`] can restore the counters if any phase fails.
    ///
    /// Ownership inversion (vs. the PR 1–3 layout): the WAL writer
    /// thread no longer performs segment I/O. Under each shard's lock
    /// the engine only captures an exact *spec* — the shard's `next_seq`
    /// cut (a cheap writer roundtrip) plus its serialized snapshot —
    /// and the write→fsync→rename of every segment then runs on a
    /// bounded side pool (`compact_threads`), concurrently across
    /// shards, with all shard locks released and commit acks still
    /// flowing. Records a shard commits after its spec simply replay on
    /// top of its segment. The **manifest commit remains the single
    /// serialization point**: [`GroupWal::finish_compact`] runs on the
    /// writer thread only after every segment cut durably completed, so
    /// a crash between segment renames and the manifest rename still
    /// recovers from the previous manifest + log tail.
    fn compact_phases(
        &self,
        wal: &GroupWal,
        cut_resets: &mut Vec<(usize, u64)>,
        fleet_cut: &mut Option<u64>,
    ) -> Result<u64, ApiError> {
        wal.begin_compact().map_err(ApiError::Storage)?;
        // One work item per study shard, plus the fleet pseudo-shard.
        // Each item captures its (spec + snapshot) lazily, right before
        // cutting, so at most pool-size snapshots are ever resident —
        // the sequential design's memory profile times the configured
        // parallelism, never times the shard count.
        let mut work: Vec<u32> = (0..self.shards.len() as u32).collect();
        work.push(FLEET_SHARD);
        let cutter = wal.segment_writer();
        let pool = self.compact_pool_size(work.len());
        self.metrics.compact_pool_threads.set(pool as f64);
        // Dirty counts consumed by cut specs, keyed by shard — the
        // caller restores them if any phase of the compaction fails.
        let consumed: Mutex<Vec<(u32, u64)>> = Mutex::new(Vec::new());
        // Fan out the cuts, join, aggregate every error: one failed cut
        // aborts the whole compaction, never a half-specified manifest.
        // The abort flag keeps the fail-fast of the sequential design —
        // after a real I/O error (disk full, say) the remaining shards
        // skip their segment I/O instead of billing a doomed manifest.
        let aborted = AtomicBool::new(false);
        let cut = |shard: u32| -> Result<Option<(u32, String, u64)>, String> {
            let result = self.compact_cut(wal, &cutter, shard, &consumed);
            if result.is_err() {
                aborted.store(true, Ordering::Relaxed);
            }
            result
        };
        let results: Vec<Result<Option<(u32, String, u64)>, String>> = if pool <= 1 {
            let mut out = Vec::new();
            for shard in work {
                if aborted.load(Ordering::Relaxed) {
                    break;
                }
                out.push(cut(shard));
            }
            out
        } else {
            let queue = Mutex::new(work.into_iter());
            let out = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for _ in 0..pool {
                    scope.spawn(|| loop {
                        if aborted.load(Ordering::Relaxed) {
                            break;
                        }
                        // Take the next shard with the queue lock
                        // already released before the (slow) cut runs.
                        let shard = queue.lock_safe().next();
                        let Some(shard) = shard else { break };
                        let result = cut(shard);
                        out.lock_safe().push(result);
                    });
                }
            });
            out.into_inner().unwrap()
        };
        for (shard, n) in consumed.into_inner().unwrap() {
            if shard == FLEET_SHARD {
                *fleet_cut = Some(n);
            } else {
                cut_resets.push((shard as usize, n));
            }
        }
        let mut segments: Vec<(u32, String, u64)> = Vec::new();
        let mut errors: Vec<String> = Vec::new();
        for result in results {
            match result {
                Ok(Some(entry)) => segments.push(entry),
                Ok(None) => {}
                Err(e) => errors.push(e),
            }
        }
        if !errors.is_empty() {
            return Err(ApiError::Storage(errors.join("; ")));
        }
        // Manifest order is layout, not timing: shard index order with
        // the fleet segment last (`FLEET_SHARD` = `u32::MAX`), whatever
        // order the pool finished in — `--compact-threads 1` therefore
        // reproduces the sequential manifest byte for byte.
        segments.sort_by_key(|(shard, _, _)| *shard);
        wal.finish_compact(
            segments,
            self.next_trial_id.load(Ordering::Relaxed),
            self.next_study_id.load(Ordering::Relaxed),
        )
        .map_err(ApiError::Storage)
    }

    /// One compaction work item, safe to run from any pool thread:
    /// under the shard's lock (the bind gate's write half plus the
    /// fleet lock for [`FLEET_SHARD`]), either reference the previous
    /// segment (clean-shard reuse) or capture the exact cut spec —
    /// `next_seq` from the writer plus the serialized snapshot — then
    /// release the lock and write the segment through `cutter`. Returns
    /// the manifest entry, `None` when the fleet segment is skipped
    /// (fleet never used). Dirty counts consumed by a cut spec are
    /// pushed to `consumed` under the same lock, so the caller can
    /// restore them if the compaction fails.
    fn compact_cut(
        &self,
        wal: &GroupWal,
        cutter: &crate::store::SegmentWriter,
        shard: u32,
        consumed: &Mutex<Vec<(u32, u64)>>,
    ) -> Result<Option<(u32, String, u64)>, String> {
        let (cut, snapshot) = if shard == FLEET_SHARD {
            // Fleet spec: the bind gate's write half (no lease_bind may
            // straddle the cut) plus the fleet lock (every other fleet
            // record is appended under it) mirror the per-shard
            // exact-spec argument. Skipped entirely while the fleet was
            // never used, reused while clean, re-cut once dirty.
            let _gate = self.fleet_bind_gate.write_safe();
            let fl = self.fleet.lock();
            let clean = self.fleet_dirty.load(Ordering::Relaxed) == 0;
            if clean {
                if let Some((file, prev)) = wal.reuse_segment(shard)? {
                    return Ok(Some((shard, file, prev)));
                }
                if fl.registry.is_empty() && fl.leases.is_empty() {
                    return Ok(None);
                }
            }
            let cut = wal.shard_cut(shard)?;
            let snapshot = fl.snapshot_json();
            consumed
                .lock_safe()
                .push((shard, self.fleet_dirty.swap(0, Ordering::Relaxed)));
            (cut, snapshot)
        } else {
            let idx = shard as usize;
            let guard = self.lock_shard(idx);
            // Clean-shard skip: no records since this shard's previous
            // segment (the dirty counter is only ever touched under
            // this shard's lock) means that segment still covers the
            // shard exactly — reference it in the new manifest instead
            // of serializing an identical snapshot.
            if self.shard_dirty[idx].load(Ordering::Relaxed) == 0 {
                if let Some((file, prev)) = wal.reuse_segment(shard)? {
                    return Ok(Some((shard, file, prev)));
                }
            }
            let cut = wal.shard_cut(shard)?;
            let snapshot = Self::shard_studies_value(&guard);
            consumed
                .lock_safe()
                .push((shard, self.shard_dirty[idx].swap(0, Ordering::Relaxed)));
            (cut, snapshot)
        };
        // Locks released: the slow write → fsync → rename runs while
        // the shard (and the fleet) keep serving; records committed
        // from here on have `seq >= cut` and replay on top.
        let t0 = Instant::now();
        let result = cutter
            .write_segment(shard, cut, &snapshot)
            .map(|file| Some((shard, file, cut)))
            .map_err(|e| format!("segment cut (shard {shard}): {e}"));
        self.metrics
            .compact_segment_seconds
            .observe(t0.elapsed().as_secs_f64());
        result
    }

    /// Size of the compaction side pool: the configured
    /// `compact_threads`, or `min(n_shards, cores)` when 0, never more
    /// threads than cut jobs.
    fn compact_pool_size(&self, jobs: usize) -> usize {
        let auto = self
            .shards
            .len()
            .min(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
        let configured = if self.config.compact_threads == 0 {
            auto
        } else {
            self.config.compact_threads
        };
        configured.max(1).min(jobs.max(1))
    }

    // ------------------------------------------------------------------
    // Persistence plumbing
    // ------------------------------------------------------------------

    /// Locate the study for `key` on `shard_idx`, creating (and
    /// persisting) it if new. Called with the shard lock held; the
    /// shard's `by_key` is authoritative for its keys, so creation
    /// races cannot duplicate a study.
    ///
    /// The directory entry for a created study is *staged*, not pushed:
    /// the registry lock (level 10) sits below the shard lock (level
    /// 20) in the canonical order, so the caller publishes the staged
    /// entry via [`Engine::publish_dir_entry`] once its shard guard is
    /// released. Callers must not early-return between a successful
    /// call and that publish.
    fn find_or_create_study(
        &self,
        state: &mut ShardState,
        shard_idx: usize,
        def: &StudyDef,
        now: f64,
        key: &str,
        staged_dir: &mut Option<DirEntry>,
    ) -> Result<usize, ApiError> {
        match state.by_key.get(key) {
            Some(&slot) => Ok(slot),
            None => {
                let id = self.next_study_id.fetch_add(1, Ordering::Relaxed);
                let ev_payload = {
                    let mut o = Value::obj();
                    // `at` rides along so a replica's `apply_event`
                    // reconstructs the same `created_at` the primary
                    // serves — the study pages must match byte-for-byte.
                    o.set("id", id).set("def", def.canonical_json()).set("at", now);
                    Value::Obj(o)
                };
                // Persist first (see `insert_trial`): a failed append
                // must not leave a study the log doesn't know about.
                self.persist(Record::new("study_new", ev_payload).with_shard(shard_idx as u32))?;
                let study = Study::new(id, def.clone(), now);
                state.studies.push(study);
                let slot = state.studies.len() - 1;
                state.by_key.insert(key.to_string(), slot);
                *staged_dir = Some(DirEntry { id, shard: shard_idx, slot });
                self.metrics.studies_created.inc();
                if let Some(sm) = self.metrics.shards.get(shard_idx) {
                    sm.studies.set(state.studies.len() as f64);
                }
                // Publish the (empty) view under the same shard lock the
                // creation applied under.
                self.views.on_study_created(&state.studies[slot]);
                Ok(slot)
            }
        }
    }

    /// Publish a directory entry staged by [`Engine::find_or_create_study`].
    /// Must be called after the owning shard guard is dropped — the
    /// directory lookup path copies the entry out before locking the
    /// shard, and the write half follows the same registry-before-shard
    /// order.
    fn publish_dir_entry(&self, staged: Option<DirEntry>) {
        if let Some(entry) = staged {
            self.directory.write_safe().push(entry);
        }
    }

    /// Durably append one record through the group-commit writer.
    /// Blocks until the record's batch is fsynced; callers hold their
    /// shard lock across this call, so per-shard WAL order equals
    /// per-shard mutation order and the compaction cut stays consistent.
    fn persist(&self, record: Record) -> Result<(), ApiError> {
        if let Some(wal) = self.wal.get() {
            let shard = record.shard;
            let t0 = Instant::now();
            let info = wal.append(record).map_err(ApiError::Storage)?;
            Self::note_wal_stages(t0, info);
            self.wal_records.fetch_add(1, Ordering::Relaxed);
            self.note_dirty(shard, 1);
        }
        Ok(())
    }

    /// Durably append a batch of records in one writer roundtrip (one
    /// shared fsync) — for bulk paths like reaping.
    fn persist_many(&self, records: Vec<Record>) -> Result<(), ApiError> {
        if records.is_empty() {
            return Ok(());
        }
        if let Some(wal) = self.wal.get() {
            let n = records.len() as u64;
            let shards: Vec<u32> = records.iter().map(|r| r.shard).collect();
            let t0 = Instant::now();
            let info = wal.append_many(records).map_err(ApiError::Storage)?;
            Self::note_wal_stages(t0, info);
            self.wal_records.fetch_add(n, Ordering::Relaxed);
            for shard in shards {
                self.note_dirty(shard, 1);
            }
        }
        Ok(())
    }

    /// Attribute a group-commit roundtrip to the active span: the time
    /// the job queued behind the writer, the shared fsync its batch
    /// paid, and the full ack round-trip wall time. No-op (three loads)
    /// when no span is installed.
    fn note_wal_stages(t0: Instant, info: WalAckInfo) {
        if obs::active() {
            obs::stage_us(Stage::WalQueue, info.queue_us);
            obs::stage_us(Stage::WalFsync, info.fsync_us);
            obs::stage(Stage::WalAck, t0.elapsed());
        }
    }

    /// Count a durably appended record against its shard's (or the
    /// fleet's) compaction dirty counter. Callers hold the matching
    /// lock across the append, so the counter agrees exactly with the
    /// segment cuts taken under the same lock.
    fn note_dirty(&self, shard: u32, n: u64) {
        if shard == FLEET_SHARD {
            self.fleet_dirty.fetch_add(n, Ordering::Relaxed);
        } else if let Some(d) = self.shard_dirty.get(shard as usize) {
            d.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// The full `/metrics` scrape body: refresh the scrape-time gauges,
    /// render every registered family, then append the slow-trace
    /// exemplar gauge so operators can jump from a latency histogram
    /// straight to `/api/trace/{id}`.
    pub fn render_metrics(&self) -> String {
        self.refresh_storage_metrics();
        let mut out = self.metrics.render();
        self.tracer.render_exemplars(&mut out);
        out
    }

    /// Mirror the WAL counters into the metrics gauges. Called by the
    /// `/metrics` handler at scrape time — not on the mutation hot path.
    pub fn refresh_storage_metrics(&self) {
        self.metrics.uptime_seconds.set(self.start.elapsed().as_secs_f64());
        self.metrics
            .wal_records
            .set(self.wal_records.load(Ordering::Relaxed) as f64);
        if let Some(wal) = self.wal.get() {
            let (batches, records, last, max) = wal.stats().snapshot();
            self.metrics.wal_commit_batches.set(batches as f64);
            self.metrics.wal_commit_records.set(records as f64);
            self.metrics.wal_commit_last_batch.set(last as f64);
            self.metrics.wal_commit_max_batch.set(max as f64);
            self.metrics
                .wal_commit_batch_limit
                .set(wal.stats().batch_limit.load(Ordering::Relaxed) as f64);
            self.metrics
                .compact_segments_reused
                .set(wal.stats().segments_reused.load(Ordering::Relaxed) as f64);
        }
        let rec = self.recovery;
        self.metrics.wal_recovered_records.set(rec.recovered_records as f64);
        self.metrics.wal_truncated_records.set(rec.truncated_records as f64);
        self.metrics.wal_truncated_bytes.set(rec.truncated_bytes as f64);
        self.metrics.wal_filtered_records.set(rec.filtered_records as f64);
        // Replication lag (follower-side; both read 0 on a primary).
        {
            let next = self.repl_next.load(Ordering::Relaxed);
            let primary_next = self.repl_primary_next.load(Ordering::Relaxed);
            self.metrics.repl_lag_seq.set(primary_next.saturating_sub(next) as f64);
            let behind_ms = self.repl_behind_since_ms.load(Ordering::Relaxed);
            let lag_seconds = if behind_ms == 0 {
                0.0
            } else {
                ((self.now() * 1000.0) as u64).saturating_sub(behind_ms) as f64 / 1000.0
            };
            self.metrics.repl_lag_seconds.set(lag_seconds);
        }
        // Fleet gauges (scrape-time snapshot of the fleet tables).
        {
            let fl = self.fleet.lock();
            self.metrics
                .fleet_workers_alive
                .set(fl.registry.count(crate::fleet::WorkerState::Alive) as f64);
            self.metrics.fleet_leases.set(fl.leases.len() as f64);
            self.metrics
                .fleet_requeue_depth
                .set(fl.leases.queue_depth() as f64);
            let loads: Vec<(String, f64)> = fl
                .sched
                .site_loads()
                .into_iter()
                .map(|(site, n)| (site, n as f64))
                .collect();
            *self.metrics.site_leases.lock_safe() = loads;
            let tenants: Vec<(String, f64)> = fl
                .sched
                .tenant_loads()
                .into_iter()
                .map(|(tenant, n)| (tenant, n as f64))
                .collect();
            *self.metrics.tenant_leases.lock_safe() = tenants;
        }
        // Read-path staleness: worst (runtime epoch − published view
        // epoch) across studies. 0 under synchronous publication; >0
        // would flag a mutation path missing its view hook.
        let mut worst_lag = 0u64;
        for shard in &self.shards {
            let guard = shard.state.lock_safe();
            for study in &guard.studies {
                let published = self.views.view_epoch(study.id).unwrap_or(0);
                worst_lag = worst_lag.max(study.runtime.epoch.saturating_sub(published));
            }
        }
        self.metrics.view_staleness_epochs.set(worst_lag as f64);
    }

    /// Rebuild every study's materialized view and event log from the
    /// in-memory state (post-recovery; also the repair path if a view
    /// were ever found stale).
    fn rebuild_views(&self) {
        for shard in &self.shards {
            let guard = shard.state.lock_safe();
            for study in &guard.studies {
                self.views.rebuild_from(study);
            }
        }
    }

    /// Refresh the per-shard gauges from the shard state (cheap; called
    /// under the shard lock).
    fn shard_metrics_update(&self, shard_idx: usize, state: &ShardState) {
        if let Some(sm) = self.metrics.shards.get(shard_idx) {
            sm.ops.inc();
            sm.tracked_running.set(state.last_seen.len() as f64);
        }
    }

    /// Compact opportunistically once the WAL outgrows the policy. Must
    /// be called with **no** shard lock held (compaction takes each of
    /// them in turn).
    fn maybe_compact(&self) {
        if self.wal.get().is_none() {
            return;
        }
        let records = self.wal_records.load(Ordering::Relaxed);
        if records < self.compact_threshold.load(Ordering::Relaxed) {
            return;
        }
        if self
            .compacting
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        match self.compact() {
            Ok(()) => {
                self.compact_threshold
                    .store(self.config.compact_after, Ordering::Relaxed);
            }
            Err(e) => {
                // Surface the failure and back off by a quarter policy
                // worth of records before retrying — tight failure loops
                // would stall mutations behind useless segment writes.
                eprintln!("hopaas: auto-compaction failed: {e}");
                self.metrics.compact_failures.inc();
                let step = (self.config.compact_after / 4).max(1);
                self.compact_threshold
                    .store(records.saturating_add(step), Ordering::Relaxed);
            }
        }
        self.compacting.store(false, Ordering::Release);
    }

    /// Serialize one shard's studies (in id order) — the body of that
    /// shard's compaction segment. Called with the shard lock held.
    fn shard_studies_value(state: &ShardState) -> Value {
        let mut with_ids: Vec<(u64, Value)> = state
            .studies
            .iter()
            .map(|s| {
                let mut o = Value::obj();
                o.set("id", s.id)
                    .set("def", s.def.canonical_json())
                    .set("created_at", s.created_at)
                    .set(
                        "trials",
                        Value::Arr(s.trials.iter().map(|t| t.to_json()).collect()),
                    );
                (s.id, Value::Obj(o))
            })
            .collect();
        with_ids.sort_by_key(|(id, _)| *id);
        Value::Arr(with_ids.into_iter().map(|(_, v)| v).collect())
    }

    /// Insert a recovered study (segment, legacy snapshot, or
    /// `study_new` event) into its shard and the directory. Called from
    /// replay-partition threads: safe because every structure it touches
    /// is locked, and the study's *own* records are confined to one
    /// partition (so no two threads ever race on the same study).
    fn recover_study(&self, mut study: Study) {
        let id = study.id;
        if let Some(max_number) = study.trials.iter().map(|t| t.number).max() {
            study.note_trial_number(max_number);
        }
        let shard_idx = self.shard_of(&study.key);
        let mut guard = self.lock_shard(shard_idx);
        let state = &mut *guard;
        if state.by_key.contains_key(&study.key) {
            // Replay idempotence: a crash inside the compaction window
            // leaves `study_new` records a segment already covers — skip
            // the duplicate.
            self.next_study_id.fetch_max(id + 1, Ordering::Relaxed);
            return;
        }
        let slot = state.studies.len();
        state.by_key.insert(study.key.clone(), slot);
        for (ti, t) in study.trials.iter().enumerate() {
            state.trial_index.insert(t.id, (slot, ti));
            self.router.insert(t.id, shard_idx);
            self.next_trial_id.fetch_max(t.id + 1, Ordering::Relaxed);
        }
        state.studies.push(study);
        if let Some(sm) = self.metrics.shards.get(shard_idx) {
            sm.studies.set(state.studies.len() as f64);
        }
        drop(guard);
        // Registry (level 10) sits below the shard lock (level 20) in
        // the canonical order, so the entry is published only after the
        // shard guard is released.
        self.directory
            .write_safe()
            .push(DirEntry { id, shard: shard_idx, slot });
        self.next_study_id.fetch_max(id + 1, Ordering::Relaxed);
    }

    /// Rebuild a [`Study`] from its snapshot JSON (segment or legacy v1
    /// snapshot entry).
    fn study_from_json(sv: &Value) -> Result<Study, ApiError> {
        let (def, _) = parse_ask_body(sv.get("def"))
            .map_err(|e| ApiError::Storage(format!("snapshot study def: {e}")))?;
        let def = StudyDef {
            // canonical_json stores name/sampler/pruner explicitly.
            name: sv.get("def").get("name").as_str().unwrap_or("default").into(),
            ..def
        };
        let id = sv.get("id").as_u64().unwrap_or(0);
        let mut study = Study::new(id, def, sv.get("created_at").as_f64().unwrap_or(0.0));
        for tv in sv.get("trials").as_arr().unwrap_or(&[]) {
            if let Some(t) = Trial::from_json(tv) {
                study.trials.push(t);
            }
        }
        Ok(study)
    }

    /// Partition recovered state for parallel replay. Studies (from
    /// segments or the legacy snapshot) and events alike are bucketed by
    /// `place(study_key, P)`: a pure function of the study definition,
    /// so one study's records always share a partition — and stay in
    /// file order within it — no matter which shard layout wrote them.
    /// Events whose parent study/trial record was lost (torn tail) are
    /// counted into `recovery.orphan_records` and dropped, exactly as
    /// the sequential replay ignored them.
    /// Fleet record tags (engine-global; replayed sequentially after
    /// the study partitions, not inside them).
    fn is_fleet_tag(tag: &str) -> bool {
        matches!(
            tag,
            "worker_register" | "worker_lost" | "worker_deregister" | "lease_bind"
                | "trial_requeue" | "site_loss"
        )
    }

    fn plan_replay(
        &self,
        loaded: LoadedState,
        recovery: &mut RecoveryStats,
    ) -> Result<(Vec<ReplayPartition>, Vec<Record>), ApiError> {
        let p_count = if self.config.replay_threads > 0 {
            self.config.replay_threads
        } else {
            self.shards.len()
        }
        .max(1);
        let mut parts: Vec<ReplayPartition> = (0..p_count)
            .map(|_| ReplayPartition { studies: Vec::new(), events: Vec::new() })
            .collect();
        let mut study_part: HashMap<u64, usize> = HashMap::new();
        let mut trial_part: HashMap<u64, usize> = HashMap::new();

        let mut snapshot_studies: Vec<&Value> = Vec::new();
        for seg in &loaded.segments {
            snapshot_studies.extend(seg.get("studies").as_arr().unwrap_or(&[]));
        }
        if let Some(snap) = &loaded.snapshot {
            snapshot_studies.extend(snap.get("studies").as_arr().unwrap_or(&[]));
        }
        for sv in snapshot_studies {
            let study = Self::study_from_json(sv)?;
            let p = place(&study.key, p_count);
            study_part.insert(study.id, p);
            for t in &study.trials {
                trial_part.insert(t.id, p);
            }
            parts[p].studies.push(study);
        }

        let mut fleet_events: Vec<Record> = Vec::new();
        for rec in loaded.events {
            if Self::is_fleet_tag(&rec.tag) {
                fleet_events.push(rec);
                continue;
            }
            let p = match rec.tag.as_str() {
                "study_new" => match parse_ask_body(rec.payload.get("def")) {
                    Ok((def, _)) => {
                        let def = StudyDef {
                            name: rec
                                .payload
                                .get("def")
                                .get("name")
                                .as_str()
                                .unwrap_or("default")
                                .into(),
                            ..def
                        };
                        let p = place(&def.key(), p_count);
                        let id = rec.payload.get("id").as_u64().unwrap_or(0);
                        study_part.insert(id, p);
                        Some(p)
                    }
                    Err(_) => None,
                },
                "trial_new" => {
                    let sid = rec.payload.get("study_id").as_u64().unwrap_or(0);
                    match study_part.get(&sid).copied() {
                        Some(p) => {
                            if let Some(tid) = rec.payload.get("trial").get("id").as_u64() {
                                trial_part.insert(tid, p);
                            }
                            Some(p)
                        }
                        None => None,
                    }
                }
                _ => rec
                    .payload
                    .get("trial_id")
                    .as_u64()
                    .and_then(|tid| trial_part.get(&tid).copied()),
            };
            match p {
                Some(p) => parts[p].events.push(rec),
                None => recovery.orphan_records += 1,
            }
        }
        Ok((parts, fleet_events))
    }

    /// Replay partitions — on one thread each when there is real
    /// parallelism to exploit, inline otherwise.
    fn apply_partitions(&self, parts: Vec<ReplayPartition>) {
        let work: Vec<ReplayPartition> = parts
            .into_iter()
            .filter(|p| !p.studies.is_empty() || !p.events.is_empty())
            .collect();
        if work.len() <= 1 {
            for part in work {
                self.apply_partition(part);
            }
            return;
        }
        std::thread::scope(|scope| {
            for part in work {
                let engine = &*self;
                scope.spawn(move || engine.apply_partition(part));
            }
        });
    }

    fn apply_partition(&self, part: ReplayPartition) {
        for study in part.studies {
            self.recover_study(study);
        }
        for ev in &part.events {
            self.apply_event(ev);
        }
    }

    fn apply_event(&self, record: &Record) {
        match record.tag.as_str() {
            "study_new" => {
                let v = &record.payload;
                if let Ok((def, _)) = parse_ask_body(v.get("def")) {
                    let def = StudyDef {
                        name: v.get("def").get("name").as_str().unwrap_or("default").into(),
                        ..def
                    };
                    let id = v.get("id").as_u64().unwrap_or(0);
                    let at = v.get("at").as_f64().unwrap_or(0.0);
                    self.recover_study(Study::new(id, def, at));
                }
            }
            "trial_new" => {
                let v = &record.payload;
                let study_id = v.get("study_id").as_u64().unwrap_or(0);
                if let Some(t) = Trial::from_json(v.get("trial")) {
                    let entry = self.directory.read_safe().lookup(study_id);
                    if let Some(DirEntry { shard, slot, .. }) = entry {
                        let mut guard = self.lock_shard(shard);
                        let state = &mut *guard;
                        self.next_trial_id.fetch_max(t.id + 1, Ordering::Relaxed);
                        if state.trial_index.contains_key(&t.id) {
                            // Already covered by the snapshot (crash in
                            // the compaction window) — skip the replay.
                            return;
                        }
                        let ti = state.studies[slot].trials.len();
                        let number = t.number;
                        state.trial_index.insert(t.id, (slot, ti));
                        self.router.insert(t.id, shard);
                        state.studies[slot].trials.push(t);
                        // Keep number reservation ahead of replayed trials.
                        state.studies[slot].note_trial_number(number);
                    }
                }
            }
            "trial_tell" => {
                let v = &record.payload;
                if let (Some(id), Some(val)) =
                    (v.get("trial_id").as_u64(), v.get("value").as_f64())
                {
                    self.replay_trial_mut(id, |trial| {
                        let _ = trial.complete(val, v.get("at").as_f64().unwrap_or(0.0));
                    });
                }
            }
            "trial_tell_mo" => {
                let v = &record.payload;
                if let (Some(id), Some(vals)) =
                    (v.get("trial_id").as_u64(), v.get("values").as_arr())
                {
                    let values: Vec<f64> = vals.iter().filter_map(Value::as_f64).collect();
                    self.replay_trial_mut(id, |trial| {
                        let _ = trial.complete_mo(values, v.get("at").as_f64().unwrap_or(0.0));
                    });
                }
            }
            "trial_report" => {
                let v = &record.payload;
                if let (Some(id), Some(step), Some(val)) = (
                    v.get("trial_id").as_u64(),
                    v.get("step").as_u64(),
                    v.get("value").as_f64(),
                ) {
                    self.replay_trial_mut(id, |trial| {
                        let _ = trial.report(step, val);
                    });
                }
            }
            "trial_prune" => {
                let v = &record.payload;
                if let Some(id) = v.get("trial_id").as_u64() {
                    self.replay_trial_mut(id, |trial| {
                        let _ = trial.prune(v.get("at").as_f64().unwrap_or(0.0));
                    });
                }
            }
            "trial_fail" => {
                let v = &record.payload;
                if let Some(id) = v.get("trial_id").as_u64() {
                    self.replay_trial_mut(id, |trial| {
                        let _ = trial.fail(v.get("at").as_f64().unwrap_or(0.0));
                    });
                }
            }
            _ => {}
        }
    }

    /// Replay helper: mutate a trial by id, ignoring unknown ids (a
    /// torn-tail WAL can reference trials whose `trial_new` was lost).
    fn replay_trial_mut(&self, trial_id: u64, f: impl FnOnce(&mut Trial)) {
        let Some(shard_idx) = self.router.get(trial_id) else { return };
        let mut guard = self.lock_shard(shard_idx);
        let state = &mut *guard;
        if let Some(&(si, ti)) = state.trial_index.get(&trial_id) {
            f(&mut state.studies[si].trials[ti]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::testutil::TempDir;

    fn ask_body(study: &str) -> Value {
        parse(&format!(
            r#"{{
            "study_name": "{study}",
            "properties": {{"x": {{"low": 0.0, "high": 1.0}}}},
            "direction": "minimize",
            "sampler": {{"name": "random"}},
            "pruner": {{"name": "median", "min_trials": 2}}
        }}"#
        ))
        .unwrap()
    }

    #[test]
    fn ask_creates_study_then_joins_it() {
        let e = Engine::in_memory(EngineConfig::default());
        let r1 = e.ask(&ask_body("s")).unwrap();
        let r2 = e.ask(&ask_body("s")).unwrap();
        assert_eq!(r1.study_id, r2.study_id);
        assert_ne!(r1.trial_id, r2.trial_id);
        assert_eq!(r1.trial_number, 0);
        assert_eq!(r2.trial_number, 1);
        assert_eq!(e.n_studies(), 1);
        // Different definition → different study.
        let r3 = e.ask(&ask_body("other")).unwrap();
        assert_ne!(r3.study_id, r1.study_id);
        assert_eq!(e.n_studies(), 2);
    }

    #[test]
    fn ask_returns_in_domain_params() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        let x = r.params.get("x").as_f64().unwrap();
        assert!((0.0..=1.0).contains(&x));
    }

    #[test]
    fn tell_finalizes_and_flags_best() {
        let e = Engine::in_memory(EngineConfig::default());
        let r1 = e.ask(&ask_body("s")).unwrap();
        let (sid, best1) = e.tell(r1.trial_id, 5.0).unwrap();
        assert_eq!(sid, r1.study_id);
        assert!(best1, "first completed is best");
        let r2 = e.ask(&ask_body("s")).unwrap();
        let (_, best2) = e.tell(r2.trial_id, 9.0).unwrap();
        assert!(!best2);
        let r3 = e.ask(&ask_body("s")).unwrap();
        let (_, best3) = e.tell(r3.trial_id, 1.0).unwrap();
        assert!(best3);
    }

    #[test]
    fn tell_twice_conflicts() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        e.tell(r.trial_id, 1.0).unwrap();
        assert!(matches!(e.tell(r.trial_id, 2.0), Err(ApiError::Conflict(_))));
    }

    #[test]
    fn tell_unknown_trial_not_found() {
        let e = Engine::in_memory(EngineConfig::default());
        assert!(matches!(e.tell(999, 1.0), Err(ApiError::NotFound(_))));
    }

    #[test]
    fn should_prune_records_and_decides() {
        let e = Engine::in_memory(EngineConfig::default());
        // Build a history of completed trials with loss 1.0 at step 1.
        for _ in 0..4 {
            let r = e.ask(&ask_body("s")).unwrap();
            e.should_prune(r.trial_id, 1, 1.0).unwrap();
            e.tell(r.trial_id, 1.0).unwrap();
        }
        // A terrible trial gets pruned.
        let bad = e.ask(&ask_body("s")).unwrap();
        let pruned = e.should_prune(bad.trial_id, 1, 100.0).unwrap();
        assert!(pruned);
        // Pruned trial can't be told.
        assert!(matches!(e.tell(bad.trial_id, 1.0), Err(ApiError::Conflict(_))));
        // A good trial survives.
        let good = e.ask(&ask_body("s")).unwrap();
        assert!(!e.should_prune(good.trial_id, 1, 0.5).unwrap());
    }

    #[test]
    fn deterministic_suggestions_per_seed() {
        let e1 = Engine::in_memory(EngineConfig::default());
        let e2 = Engine::in_memory(EngineConfig::default());
        for _ in 0..5 {
            let a = e1.ask(&ask_body("s")).unwrap();
            let b = e2.ask(&ask_body("s")).unwrap();
            assert_eq!(a.params.to_string(), b.params.to_string());
            e1.tell(a.trial_id, 1.0).unwrap();
            e2.tell(b.trial_id, 1.0).unwrap();
        }
    }

    #[test]
    fn suggestion_stream_identical_across_shard_counts() {
        // The sharding refactor must not perturb the per-study
        // suggestion stream: 1 shard (the seed's single-lock layout) and
        // 8 shards draw byte-identical parameter sequences.
        for sampler_rich_study in ["alpha", "beta", "gamma"] {
            let e1 = Engine::in_memory(EngineConfig { n_shards: 1, ..Default::default() });
            let e8 = Engine::in_memory(EngineConfig { n_shards: 8, ..Default::default() });
            for i in 0..6 {
                let a = e1.ask(&ask_body(sampler_rich_study)).unwrap();
                let b = e8.ask(&ask_body(sampler_rich_study)).unwrap();
                assert_eq!(
                    a.params.to_string(),
                    b.params.to_string(),
                    "study {sampler_rich_study} trial {i}"
                );
                e1.tell(a.trial_id, i as f64).unwrap();
                e8.tell(b.trial_id, i as f64).unwrap();
            }
        }
    }

    #[test]
    fn studies_spread_across_shards() {
        let e = Engine::in_memory(EngineConfig { n_shards: 8, ..Default::default() });
        for i in 0..32 {
            e.ask(&ask_body(&format!("spread-{i}"))).unwrap();
        }
        assert_eq!(e.n_studies(), 32);
        let occupied = e
            .metrics
            .shards
            .iter()
            .filter(|s| s.studies.get() > 0.0)
            .count();
        assert!(occupied >= 4, "32 studies landed on only {occupied}/8 shards");
        // Read APIs see all studies in id order.
        let ids: Vec<u64> = e
            .studies_json()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("id").as_u64().unwrap())
            .collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        assert_eq!(ids.len(), 32);
    }

    #[test]
    fn last_seen_cleaned_up_on_terminal_states() {
        let e = Engine::in_memory(EngineConfig::default());
        let told = e.ask(&ask_body("s")).unwrap();
        let failed = e.ask(&ask_body("s")).unwrap();
        let reported = e.ask(&ask_body("s")).unwrap();
        assert_eq!(e.tracked_running(), 3);
        e.should_prune(reported.trial_id, 1, 0.5).unwrap();
        assert_eq!(e.tracked_running(), 3, "report keeps the trial tracked");
        e.tell(told.trial_id, 1.0).unwrap();
        assert_eq!(e.tracked_running(), 2, "tell releases tracking");
        e.fail(failed.trial_id).unwrap();
        assert_eq!(e.tracked_running(), 1, "fail releases tracking");
        e.tell(reported.trial_id, 2.0).unwrap();
        assert_eq!(e.tracked_running(), 0, "no leak once all trials finish");
    }

    #[test]
    fn durable_recovery_exact() {
        let d = TempDir::new("engine-recover");
        let (study_id, told, running_id);
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            let r1 = e.ask(&ask_body("s")).unwrap();
            study_id = r1.study_id;
            e.should_prune(r1.trial_id, 1, 0.9).unwrap();
            e.tell(r1.trial_id, 0.42).unwrap();
            told = r1.trial_id;
            let r2 = e.ask(&ask_body("s")).unwrap();
            running_id = r2.trial_id;
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        assert_eq!(e.n_studies(), 1);
        let trials = e.trials_json(study_id).unwrap();
        let arr = trials.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let t0 = arr.iter().find(|t| t.get("id").as_u64() == Some(told)).unwrap();
        assert_eq!(t0.get("state").as_str(), Some("completed"));
        assert_eq!(t0.get("value").as_f64(), Some(0.42));
        let t1 = arr.iter().find(|t| t.get("id").as_u64() == Some(running_id)).unwrap();
        assert_eq!(t1.get("state").as_str(), Some("running"));
        // New trials continue the id sequence without collision.
        let r3 = e.ask(&ask_body("s")).unwrap();
        assert!(r3.trial_id > running_id);
    }

    #[test]
    fn recovery_after_compaction() {
        let d = TempDir::new("engine-compact");
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            for i in 0..6 {
                let r = e.ask(&ask_body("s")).unwrap();
                e.tell(r.trial_id, i as f64).unwrap();
            }
            e.compact().unwrap();
            let r = e.ask(&ask_body("s")).unwrap();
            e.tell(r.trial_id, -1.0).unwrap();
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        let sid = e.study_id_by_key(
            &parse_ask_body(&ask_body("s")).unwrap().0.key(),
        );
        let sid = sid.unwrap();
        let trials = e.trials_json(sid).unwrap();
        assert_eq!(trials.as_arr().unwrap().len(), 7);
        let best = e.best_curve(sid).unwrap();
        assert_eq!(best.last().unwrap().1, -1.0);
    }

    #[test]
    fn crash_between_manifest_and_log_gc_recovers_once() {
        // Incremental compaction commits the manifest and then deletes
        // the sealed pre-rotation log; a crash between those two steps
        // leaves segments *plus* the full pre-compaction log. Replay
        // must be idempotent — no duplicated studies or trials.
        let d = TempDir::new("engine-compact-crash");
        let wal_path = d.path().join("wal.log");
        let pre_wal;
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            for s in 0..3 {
                for i in 0..2 {
                    let r = e.ask(&ask_body(&format!("cw-{s}"))).unwrap();
                    e.tell(r.trial_id, i as f64).unwrap();
                }
            }
            pre_wal = std::fs::read(&wal_path).unwrap();
            e.compact().unwrap();
        }
        // Simulate the crash window: manifest + segments in place, the
        // sealed epoch-0 log never garbage-collected.
        std::fs::write(&wal_path, &pre_wal).unwrap();
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        assert_eq!(e.n_studies(), 3, "studies must not be duplicated");
        for s in e.studies_json().as_arr().unwrap() {
            assert_eq!(s.get("n_trials").as_i64(), Some(2));
            assert_eq!(s.get("n_completed").as_i64(), Some(2));
        }
        // All 15 covered records (3 × study_new + 6 × trial_new +
        // 6 × trial_tell) were skipped, not re-applied.
        assert_eq!(e.recovery_stats().filtered_records, 15);
        assert_eq!(e.recovery_stats().segments as usize, e.n_shards());
        // Still serves new trials with correct numbering.
        let r = e.ask(&ask_body("cw-0")).unwrap();
        assert_eq!(r.trial_number, 2);
    }

    #[test]
    fn recovery_with_mixed_shard_history() {
        // The same log can carry records stamped under different shard
        // layouts (server restarted with a new --shards). The replay
        // partitioner groups by *study*, not by recorded shard index,
        // so such logs recover exactly.
        let d = TempDir::new("engine-mixed");
        let told;
        {
            let e = Engine::open(d.path(), EngineConfig { n_shards: 8, ..Default::default() })
                .unwrap();
            let r = e.ask(&ask_body("mixed")).unwrap();
            e.tell(r.trial_id, 1.0).unwrap();
            told = r.trial_id;
        }
        {
            // Reopen with 2 shards: the same study's new records carry
            // 2-shard indices into the same epoch-0 log.
            let e = Engine::open(d.path(), EngineConfig { n_shards: 2, ..Default::default() })
                .unwrap();
            let r = e.ask(&ask_body("mixed")).unwrap();
            e.tell(r.trial_id, 2.0).unwrap();
        }
        let e = Engine::open(d.path(), EngineConfig { n_shards: 4, ..Default::default() })
            .unwrap();
        assert_eq!(e.n_studies(), 1);
        let sid = e.studies_json().at(0).get("id").as_u64().unwrap();
        let trials = e.trials_json(sid).unwrap();
        assert_eq!(trials.as_arr().unwrap().len(), 2);
        let t0 = trials
            .as_arr()
            .unwrap()
            .iter()
            .find(|t| t.get("id").as_u64() == Some(told))
            .unwrap();
        assert_eq!(t0.get("value").as_f64(), Some(1.0));
        assert_eq!(e.recovery_stats().orphan_records, 0);
        assert_eq!(e.recovery_stats().seq_order_violations, 0);
        // Numbering continues without collision.
        let r = e.ask(&ask_body("mixed")).unwrap();
        assert_eq!(r.trial_number, 2);
    }

    #[test]
    fn concurrent_same_study_asks_reserve_distinct_numbers() {
        // The trial number seeds the suggestion RNG, so two asks racing
        // on one study must never share it (the seed engine's documented
        // duplicate-suggestion race). 8 threads × 10 asks on one study:
        // numbers are exactly 0..80, and each number's params match the
        // pure function of (seed, key, number) a sequential engine draws.
        let e = Arc::new(Engine::in_memory(EngineConfig::default()));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let e = e.clone();
                std::thread::spawn(move || {
                    (0..10)
                        .map(|_| {
                            let r = e.ask(&ask_body("hot")).unwrap();
                            (r.trial_number, r.params.to_string())
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let mut got: Vec<(u64, String)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        got.sort();
        let numbers: Vec<u64> = got.iter().map(|(n, _)| *n).collect();
        assert_eq!(numbers, (0..80).collect::<Vec<u64>>(), "numbers unique + contiguous");
        let seq = Engine::in_memory(EngineConfig::default());
        for (n, params) in &got {
            let r = seq.ask(&ask_body("hot")).unwrap();
            assert_eq!(r.trial_number, *n);
            assert_eq!(&r.params.to_string(), params, "trial {n} diverged");
        }
    }

    #[test]
    fn compaction_runs_concurrently_with_mutations() {
        // Incremental compaction pauses one shard at a time; traffic on
        // every study keeps flowing while it runs, and nothing is lost
        // or doubled across the recovery that follows.
        let d = TempDir::new("engine-live-compact");
        let acked: Vec<(u64, f64)>;
        {
            let e = Arc::new(Engine::open(d.path(), EngineConfig::default()).unwrap());
            let stop = Arc::new(AtomicBool::new(false));
            let workers: Vec<_> = (0..4)
                .map(|t| {
                    let e = e.clone();
                    let stop = stop.clone();
                    std::thread::spawn(move || {
                        let body = ask_body(&format!("live-{t}"));
                        let mut acked = Vec::new();
                        let mut i = 0u64;
                        while !stop.load(Ordering::Relaxed) || i < 5 {
                            let r = e.ask(&body).unwrap();
                            let v = (t * 1000 + i) as f64;
                            e.tell(r.trial_id, v).unwrap();
                            acked.push((r.trial_id, v));
                            i += 1;
                            if i >= 200 {
                                break;
                            }
                        }
                        acked
                    })
                })
                .collect();
            for _ in 0..3 {
                e.compact().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
            acked = workers.into_iter().flat_map(|h| h.join().unwrap()).collect();
            e.compact().unwrap();
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        let mut recovered = std::collections::HashMap::new();
        for s in e.studies_json().as_arr().unwrap() {
            let sid = s.get("id").as_u64().unwrap();
            for t in e.trials_json(sid).unwrap().as_arr().unwrap() {
                if let (Some(id), Some(v)) = (t.get("id").as_u64(), t.get("value").as_f64()) {
                    assert!(recovered.insert(id, v).is_none(), "trial {id} duplicated");
                }
            }
        }
        assert_eq!(recovered.len(), acked.len());
        for (id, v) in &acked {
            assert_eq!(recovered.get(id), Some(v), "acknowledged tell {id} lost");
        }
    }

    #[test]
    fn parallel_compaction_reproduces_sequential_layout() {
        // `--compact-threads 1` must keep today's byte-identical on-disk
        // layout, and a 4-thread pool must commit the *same manifest*
        // (layout is sorted by shard, not by pool completion order) and
        // recover exactly. The manifest carries no timestamps, so the
        // two runs' MANIFEST.json bytes are comparable directly.
        fn run(dir: &std::path::Path, threads: usize) {
            let e = Engine::open(
                dir,
                EngineConfig { n_shards: 4, compact_threads: threads, ..Default::default() },
            )
            .unwrap();
            for s in 0..6 {
                for i in 0..3 {
                    let r = e.ask(&ask_body(&format!("pc-{s}"))).unwrap();
                    e.tell(r.trial_id, i as f64).unwrap();
                }
            }
            e.compact().unwrap();
        }
        fn listing(dir: &std::path::Path) -> Vec<String> {
            let mut names: Vec<String> = std::fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                .collect();
            names.sort();
            names
        }
        let seq = TempDir::new("engine-pc-seq");
        let par = TempDir::new("engine-pc-par");
        run(seq.path(), 1);
        run(par.path(), 4);
        assert_eq!(
            std::fs::read_to_string(seq.path().join("MANIFEST.json")).unwrap(),
            std::fs::read_to_string(par.path().join("MANIFEST.json")).unwrap(),
            "manifest is layout, not pool timing"
        );
        assert_eq!(listing(seq.path()), listing(par.path()), "same file set on disk");
        // The parallel-compacted directory recovers exactly.
        let e = Engine::open(par.path(), EngineConfig { n_shards: 4, ..Default::default() })
            .unwrap();
        assert_eq!(e.n_studies(), 6);
        for sv in e.studies_json().as_arr().unwrap() {
            assert_eq!(sv.get("n_completed").as_i64(), Some(3));
        }
        assert_eq!(e.recovery_stats().recovered_records, 0, "everything in segments");
    }

    #[test]
    fn worker_less_ask_rate_bounds_legacy_tenants() {
        let e = Engine::in_memory(EngineConfig {
            tenant_ask_rate: 2,
            tenant_ask_window: 3600.0,
            ..Default::default()
        });
        // Two asks fit the window; the third is denied with the tenant
        // named and the per-tenant 429 series incremented.
        e.ask_as(&ask_body("rate"), Some("alice")).unwrap();
        e.ask_as(&ask_body("rate"), Some("alice")).unwrap();
        let err = e.ask_as(&ask_body("rate"), Some("alice")).unwrap_err();
        assert!(matches!(err, ApiError::Quota(_)), "{err}");
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        assert_eq!(e.metrics.fleet_quota_denials.get(), 1);
        assert_eq!(
            e.metrics.tenant_denials.lock().unwrap().get("alice").copied(),
            Some(1)
        );
        // Other tenants and tenant-less legacy asks are unaffected.
        e.ask_as(&ask_body("rate"), Some("bob")).unwrap();
        e.ask_as(&ask_body("rate"), None).unwrap();
        // Worker-bound asks are bounded by lease quotas, not the rate
        // ledger — alice's worker keeps asking.
        let (w, _) = e.register_worker("n1", "cloud", "gpu").unwrap();
        let mut body = ask_body("rate");
        if let Value::Obj(o) = &mut body {
            o.set("worker", w);
        }
        e.ask_as(&body, Some("alice")).unwrap();
    }

    #[test]
    fn recovery_identical_across_shard_counts() {
        // A WAL written by an 8-shard engine recovers exactly on a
        // 2-shard engine: routing is derived from study keys, not from
        // the writing engine's layout.
        let d = TempDir::new("engine-reshard");
        {
            let e = Engine::open(d.path(), EngineConfig { n_shards: 8, ..Default::default() })
                .unwrap();
            for s in 0..4 {
                for i in 0..3 {
                    let r = e.ask(&ask_body(&format!("re-{s}"))).unwrap();
                    e.tell(r.trial_id, (s * 10 + i) as f64).unwrap();
                }
            }
        }
        let e = Engine::open(d.path(), EngineConfig { n_shards: 2, ..Default::default() })
            .unwrap();
        assert_eq!(e.n_studies(), 4);
        let studies = e.studies_json();
        for sv in studies.as_arr().unwrap() {
            assert_eq!(sv.get("n_completed").as_i64(), Some(3));
        }
    }

    fn ask_body_worker(study: &str, worker: u64) -> Value {
        let mut v = ask_body(study);
        if let Value::Obj(o) = &mut v {
            o.set("worker", worker);
        }
        v
    }

    #[test]
    fn lease_expiry_requeues_preempted_trials_deterministically() {
        let cfg = EngineConfig { lease_timeout: Some(0.01), ..Default::default() };
        let e = Engine::in_memory(cfg);
        let (w1, ttl) = e.register_worker("n1", "spot", "gpu").unwrap();
        assert_eq!(ttl, Some(0.01));
        let r1 = e.ask(&ask_body_worker("s", w1)).unwrap();
        let r2 = e.ask(&ask_body_worker("s", w1)).unwrap();
        assert!(!r1.requeued && !r2.requeued);
        // The worker vanishes: no heartbeat past the deadline.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(e.expire_leases(), 2);
        assert_eq!(e.expire_leases(), 0, "expiry is exactly-once");
        assert!(matches!(e.worker_heartbeat(w1), Err(ApiError::Conflict(_))));
        // A replacement worker receives both trials back — identical
        // id, number and parameters (FIFO by creation order).
        let (w2, _) = e.register_worker("n2", "spot", "gpu").unwrap();
        let q1 = e.ask(&ask_body_worker("s", w2)).unwrap();
        let q2 = e.ask(&ask_body_worker("s", w2)).unwrap();
        assert!(q1.requeued && q2.requeued);
        assert_eq!(
            (q1.trial_id, q1.trial_number, q1.params.to_string()),
            (r1.trial_id, r1.trial_number, r1.params.to_string())
        );
        assert_eq!(q2.trial_id, r2.trial_id);
        // The next fresh ask continues the number sequence: preemption
        // never perturbs the deterministic suggestion stream.
        let q3 = e.ask(&ask_body_worker("s", w2)).unwrap();
        assert!(!q3.requeued);
        assert_eq!(q3.trial_number, 2);
        let clean = Engine::in_memory(EngineConfig::default());
        for expected in [&r1, &r2, &q3] {
            let c = clean.ask(&ask_body("s")).unwrap();
            assert_eq!(c.trial_number, expected.trial_number);
            assert_eq!(c.params.to_string(), expected.params.to_string());
        }
        e.tell(q1.trial_id, 1.0).unwrap();
        e.tell(q2.trial_id, 2.0).unwrap();
        e.tell(q3.trial_id, 3.0).unwrap();
        assert_eq!(e.fleet().lock().leases.len(), 0, "tells released every lease");
        assert_eq!(e.fleet().lock().leases.queue_depth(), 0);
    }

    #[test]
    fn requeue_budget_exhaustion_fails_the_trial() {
        let cfg = EngineConfig {
            lease_timeout: Some(0.01),
            requeue_max: 1,
            ..Default::default()
        };
        let e = Engine::in_memory(cfg);
        let (w1, _) = e.register_worker("n1", "spot", "gpu").unwrap();
        let r = e.ask(&ask_body_worker("s", w1)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(e.expire_leases(), 1, "first loss: requeued");
        let (w2, _) = e.register_worker("n2", "spot", "gpu").unwrap();
        let q = e.ask(&ask_body_worker("s", w2)).unwrap();
        assert!(q.requeued);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(e.expire_leases(), 1, "second loss: budget spent, failed");
        assert!(matches!(e.tell(r.trial_id, 1.0), Err(ApiError::Conflict(_))));
        let fl = e.fleet().lock();
        assert_eq!(fl.leases.queue_depth(), 0);
        assert_eq!(fl.leases.len(), 0);
    }

    #[test]
    fn graceful_deregister_requeues_immediately() {
        let e = Engine::in_memory(EngineConfig::default());
        let (w1, _) = e.register_worker("n1", "cloud", "gpu").unwrap();
        let r = e.ask(&ask_body_worker("s", w1)).unwrap();
        // No lease-timeout wait: deregistration hands the trial back.
        assert_eq!(e.deregister_worker(w1).unwrap(), 1);
        let (w2, _) = e.register_worker("n2", "cloud", "gpu").unwrap();
        let q = e.ask(&ask_body_worker("s", w2)).unwrap();
        assert!(q.requeued);
        assert_eq!(q.trial_id, r.trial_id);
        e.tell(q.trial_id, 1.0).unwrap();
    }

    #[test]
    fn fleet_state_survives_recovery_and_compaction() {
        let d = TempDir::new("engine-fleet-recover");
        let (w1, r1_id, r2_id);
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            let (w, _) = e.register_worker("n1", "infn-cloud", "a100").unwrap();
            w1 = w;
            let r1 = e.ask(&ask_body_worker("s", w)).unwrap();
            let r2 = e.ask(&ask_body_worker("s", w)).unwrap();
            r1_id = r1.trial_id;
            r2_id = r2.trial_id;
            e.tell(r2.trial_id, 1.0).unwrap();
        }
        // Reopen: the worker and its one live lease survive; the lease
        // released by the tell stays released.
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        {
            let fl = e.fleet().lock();
            assert_eq!(fl.registry.len(), 1);
            assert_eq!(fl.registry.get(w1).unwrap().site, "infn-cloud");
            assert_eq!(fl.leases.len(), 1);
            assert!(fl.leases.is_leased(r1_id));
            assert!(!fl.leases.is_leased(r2_id));
        }
        // Deadlines were reset: the surviving worker can heartbeat.
        assert_eq!(e.worker_heartbeat(w1).unwrap(), 1);
        // Compaction writes the fleet segment; a reopen that reads no
        // log records at all still reconstructs the fleet.
        e.compact().unwrap();
        drop(e);
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        assert_eq!(e.recovery_stats().recovered_records, 0, "state came from segments");
        {
            let fl = e.fleet().lock();
            assert_eq!(fl.registry.len(), 1);
            assert!(fl.leases.is_leased(r1_id));
        }
        e.tell(r1_id, 0.5).unwrap();
        assert_eq!(e.fleet().lock().leases.len(), 0);
    }

    #[test]
    fn second_compaction_skips_clean_shards() {
        let d = TempDir::new("engine-clean-skip");
        let cfg = EngineConfig { n_shards: 4, ..Default::default() };
        let e = Engine::open(d.path(), cfg.clone()).unwrap();
        for s in 0..8 {
            let r = e.ask(&ask_body(&format!("skip-{s}"))).unwrap();
            e.tell(r.trial_id, s as f64).unwrap();
        }
        e.compact().unwrap();
        let stats = e.stats_json();
        assert_eq!(
            stats.get("wal_commit").get("segments_reused").as_u64(),
            Some(0),
            "first compaction cuts everything"
        );
        // Touch exactly one study → exactly one dirty shard.
        let r = e.ask(&ask_body("skip-0")).unwrap();
        e.tell(r.trial_id, 9.0).unwrap();
        e.compact().unwrap();
        let stats = e.stats_json();
        assert_eq!(
            stats.get("wal_commit").get("segments_reused").as_u64(),
            Some(3),
            "three clean shards reused their segments"
        );
        // Nothing new at all: every shard reuses.
        e.compact().unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("wal_commit").get("segments_reused").as_u64(), Some(7));
        drop(e);
        // Recovery over the reused-segment manifest is exact.
        let e = Engine::open(d.path(), cfg).unwrap();
        assert_eq!(e.n_studies(), 8);
        assert_eq!(e.recovery_stats().segments, 4);
        let total: i64 = e
            .studies_json()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("n_completed").as_i64().unwrap())
            .sum();
        assert_eq!(total, 9, "every acknowledged tell recovered");
        // Reuse works across a restart too: the loaded manifest seeds
        // the reuse table (the layout matched), and nothing is dirty.
        e.compact().unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("wal_commit").get("segments_reused").as_u64(), Some(4));
    }

    #[test]
    fn reap_skips_leased_trials() {
        let cfg = EngineConfig {
            reap_after: Some(0.0),
            lease_timeout: Some(60.0),
            ..Default::default()
        };
        let e = Engine::in_memory(cfg);
        let (w, _) = e.register_worker("n1", "cloud", "gpu").unwrap();
        let leased = e.ask(&ask_body_worker("s", w)).unwrap();
        let legacy = e.ask(&ask_body("s")).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(e.reap_stale(), 1, "only the worker-less trial is reaped");
        assert!(matches!(e.tell(legacy.trial_id, 1.0), Err(ApiError::Conflict(_))));
        e.tell(leased.trial_id, 1.0).unwrap();
    }

    #[test]
    fn reaper_bounds_queued_trial_wait() {
        // A requeued trial gets one full reap window to find a new
        // worker (the requeue refreshed `last_seen`); if none arrives,
        // the reaper fails it and scrubs its fleet entries — the
        // pre-fleet "every silent trial is bounded by reap_after"
        // guarantee holds for queued trials too.
        let cfg = EngineConfig {
            reap_after: Some(0.05),
            lease_timeout: Some(0.01),
            ..Default::default()
        };
        let e = Engine::in_memory(cfg);
        let (w, _) = e.register_worker("n1", "spot", "gpu").unwrap();
        let r = e.ask(&ask_body_worker("s", w)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(e.expire_leases(), 1);
        // Within the window the queued trial is left alone…
        assert_eq!(e.reap_stale(), 0, "queue gets its full reap window");
        assert_eq!(e.fleet().lock().leases.queue_depth(), 1);
        // …but once it has waited a full reap_after unclaimed, it goes.
        std::thread::sleep(std::time::Duration::from_millis(70));
        assert_eq!(e.reap_stale(), 1);
        assert_eq!(e.fleet().lock().leases.queue_depth(), 0, "fleet entries scrubbed");
        assert!(matches!(e.tell(r.trial_id, 1.0), Err(ApiError::Conflict(_))));
    }

    #[test]
    fn reap_marks_stale_failed() {
        let mut cfg = EngineConfig::default();
        cfg.reap_after = Some(0.0); // everything is instantly stale
        let e = Engine::in_memory(cfg);
        let r = e.ask(&ask_body("s")).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(e.reap_stale(), 1);
        assert!(matches!(e.tell(r.trial_id, 1.0), Err(ApiError::Conflict(_))));
        assert_eq!(e.tracked_running(), 0);
    }

    #[test]
    fn series_and_study_json() {
        let e = Engine::in_memory(EngineConfig::default());
        let r = e.ask(&ask_body("s")).unwrap();
        e.should_prune(r.trial_id, 1, 3.0).unwrap();
        e.should_prune(r.trial_id, 2, 2.0).unwrap();
        e.tell(r.trial_id, 2.0).unwrap();
        let series = e.series_json(r.study_id).unwrap();
        let pts = series.at(0).get("points");
        assert_eq!(pts.at(0).at(1).as_f64(), Some(3.0));
        assert_eq!(series.at(0).get("final").as_f64(), Some(2.0));
        let sj = e.study_json(r.study_id).unwrap();
        assert_eq!(sj.get("n_completed").as_i64(), Some(1));
        assert!(e.study_json(999).is_none());
    }

    #[test]
    fn stats_json_shape() {
        let d = TempDir::new("engine-stats");
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        let r = e.ask(&ask_body("s")).unwrap();
        e.tell(r.trial_id, 1.0).unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("shards").as_u64(), Some(8));
        assert_eq!(stats.get("studies").as_u64(), Some(1));
        assert_eq!(stats.get("asks").as_u64(), Some(1));
        assert_eq!(stats.get("tracked_running").as_u64(), Some(0));
        assert_eq!(stats.get("durable").as_bool(), Some(true));
        let wal = stats.get("wal_commit");
        // study_new + trial_new + trial_tell committed.
        assert_eq!(wal.get("records").as_u64(), Some(3));
        assert!(wal.get("batches").as_u64().unwrap() >= 1);
        // Recovery block is always present; this engine started from an
        // empty directory.
        let rec = stats.get("wal_recovery");
        assert_eq!(rec.get("recovered_records").as_u64(), Some(0));
        assert_eq!(rec.get("truncated_records").as_u64(), Some(0));
        // Replication block: a durable default engine is a writable
        // primary serving a log window that covers its three records.
        let repl = stats.get("repl");
        assert_eq!(repl.get("role").as_str(), Some("primary"));
        assert_eq!(repl.get("writable").as_bool(), Some(true));
        assert_eq!(repl.get("lag_seq").as_u64(), Some(0));
        assert_eq!(repl.get("log_next").as_u64(), Some(3));
        drop(e);
        // Reopen: the three records replay and show up in the stats.
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        let rec = e.stats_json();
        let rec = rec.get("wal_recovery");
        assert_eq!(rec.get("recovered_records").as_u64(), Some(3));
        assert_eq!(rec.get("filtered_records").as_u64(), Some(0));
        assert_eq!(rec.get("orphan_records").as_u64(), Some(0));
    }

    /// TPE body with a low startup so the model (and therefore the fit
    /// cache) is exercised after a handful of tells.
    fn ask_body_tpe(study: &str) -> Value {
        parse(&format!(
            r#"{{
            "study_name": "{study}",
            "properties": {{
                "x": {{"low": 0.0, "high": 1.0}},
                "lr": {{"low": 1e-4, "high": 1.0, "type": "loguniform"}}
            }},
            "direction": "minimize",
            "sampler": {{"name": "tpe", "n_startup_trials": 4}}
        }}"#
        ))
        .unwrap()
    }

    #[test]
    fn batched_ask_rejects_bad_n_without_side_effects() {
        let e = Engine::in_memory(EngineConfig::default());
        assert!(matches!(
            e.ask_n_as(&ask_body("s"), 0, None),
            Err(ApiError::BadRequest(_))
        ));
        assert!(matches!(
            e.ask_n_as(&ask_body("s"), MAX_ASK_BATCH + 1, None),
            Err(ApiError::BadRequest(_))
        ));
        // Unknown sampler names are rejected before the study (or any
        // trial of the batch) exists.
        let mut bad = ask_body("s");
        if let Value::Obj(o) = &mut bad {
            o.set("sampler", Value::Str("annealing".into()));
        }
        assert!(matches!(e.ask_n_as(&bad, 2, None), Err(ApiError::BadRequest(_))));
        assert_eq!(e.n_studies(), 0, "rejected asks must leave no trace");
        assert_eq!(e.metrics.trials_created.get(), 0);
    }

    #[test]
    fn batched_ask_byte_identical_to_sequential() {
        // One n=6 batch must draw exactly what 6 sequential asks draw
        // (no tells in between on either engine: both fit from the same
        // frozen history), on every shard layout.
        for shards in [1usize, 4, 8] {
            let seq = Engine::in_memory(EngineConfig { n_shards: shards, ..Default::default() });
            let bat = Engine::in_memory(EngineConfig { n_shards: shards, ..Default::default() });
            // Identical scored history past TPE startup on both engines.
            for i in 0..8 {
                let a = seq.ask(&ask_body_tpe("b")).unwrap();
                let b = bat.ask(&ask_body_tpe("b")).unwrap();
                assert_eq!(a.params.to_string(), b.params.to_string());
                let v = (i as f64 * 0.7).sin();
                seq.tell(a.trial_id, v).unwrap();
                bat.tell(b.trial_id, v).unwrap();
            }
            let singles: Vec<AskReply> =
                (0..6).map(|_| seq.ask(&ask_body_tpe("b")).unwrap()).collect();
            let batch = bat.ask_n_as(&ask_body_tpe("b"), 6, None).unwrap();
            assert_eq!(batch.len(), 6);
            for (a, b) in singles.iter().zip(&batch) {
                assert_eq!(a.trial_number, b.trial_number, "shards={shards}");
                assert_eq!(
                    a.params.to_string(),
                    b.params.to_string(),
                    "shards={shards} trial {}",
                    a.trial_number
                );
            }
        }
    }

    #[test]
    fn fit_cache_transparent_and_counted() {
        let on = Engine::in_memory(EngineConfig::default());
        let off = Engine::in_memory(EngineConfig { sampler_cache: false, ..Default::default() });
        // Interleaved traffic: within a round the 2nd/3rd asks reuse the
        // fit on the cached engine and refit on the uncached one; the
        // suggestion streams must stay byte-identical regardless.
        for round in 0..6u64 {
            let mut ids = Vec::new();
            for _ in 0..3 {
                let a = on.ask(&ask_body_tpe("c")).unwrap();
                let b = off.ask(&ask_body_tpe("c")).unwrap();
                assert_eq!(a.params.to_string(), b.params.to_string(), "round {round}");
                ids.push((a.trial_id, b.trial_id));
            }
            for (k, (ia, ib)) in ids.into_iter().enumerate() {
                let v = (round * 3 + k as u64) as f64 * 0.31;
                on.tell(ia, v).unwrap();
                off.tell(ib, v).unwrap();
            }
        }
        // 3 asks per round share one fit with the cache on…
        assert_eq!(on.metrics.sampler_cache_misses.get(), 6);
        assert_eq!(on.metrics.sampler_cache_hits.get(), 12);
        // …and every ask refits with it off.
        assert_eq!(off.metrics.sampler_cache_hits.get(), 0);
        assert_eq!(off.metrics.sampler_cache_misses.get(), 18);
        // The cache decisions surface in /api/stats.
        let stats = on.stats_json();
        assert_eq!(stats.get("sampler").get("cache").as_bool(), Some(true));
        assert_eq!(stats.get("sampler").get("cache_hits").as_u64(), Some(12));
        let stats = off.stats_json();
        assert_eq!(stats.get("sampler").get("cache").as_bool(), Some(false));
    }

    #[test]
    fn historyless_samplers_skip_snapshot_and_cache() {
        // random never reads the history: no cache decision, no fit
        // timing, and asks stay cheap at any history size.
        let e = Engine::in_memory(EngineConfig::default());
        for i in 0..5 {
            let r = e.ask(&ask_body("plain")).unwrap();
            e.tell(r.trial_id, i as f64).unwrap();
        }
        assert_eq!(e.metrics.sampler_cache_hits.get(), 0);
        assert_eq!(e.metrics.sampler_cache_misses.get(), 0);
        assert_eq!(e.metrics.sampler_fit_seconds.count(), 0);
    }

    #[test]
    fn fit_cache_invalidation_survives_recovery() {
        // A restarted server must refit from the replayed history — no
        // cache state survives in the WAL — and its post-restart
        // suggestion stream must match an engine that never restarted.
        let d = TempDir::new("engine-fit-cache-recovery");
        let cont = Engine::in_memory(EngineConfig::default());
        {
            let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
            for i in 0..7 {
                let a = e.ask(&ask_body_tpe("r")).unwrap();
                let c = cont.ask(&ask_body_tpe("r")).unwrap();
                assert_eq!(a.params.to_string(), c.params.to_string());
                let v = (i as f64).cos();
                e.tell(a.trial_id, v).unwrap();
                cont.tell(c.trial_id, v).unwrap();
            }
            // Warm the fit cache right before the "crash" (this trial
            // stays running across the restart).
            let warm = e.ask(&ask_body_tpe("r")).unwrap();
            let cwarm = cont.ask(&ask_body_tpe("r")).unwrap();
            assert_eq!(warm.params.to_string(), cwarm.params.to_string());
        }
        let e = Engine::open(d.path(), EngineConfig::default()).unwrap();
        for i in 0..5 {
            let a = e.ask(&ask_body_tpe("r")).unwrap();
            let c = cont.ask(&ask_body_tpe("r")).unwrap();
            assert_eq!(a.trial_number, c.trial_number);
            assert_eq!(
                a.params.to_string(),
                c.params.to_string(),
                "post-restart trial {i} diverged"
            );
            let v = i as f64 * 0.2;
            e.tell(a.trial_id, v).unwrap();
            cont.tell(c.trial_id, v).unwrap();
        }
    }

    #[test]
    fn batched_ask_drains_requeued_first() {
        let cfg = EngineConfig { lease_timeout: Some(0.01), ..Default::default() };
        let e = Engine::in_memory(cfg);
        let (w1, _) = e.register_worker("n1", "spot", "gpu").unwrap();
        let first = e.ask_n_as(&ask_body_worker("s", w1), 2, None).unwrap();
        assert_eq!(first.len(), 2);
        // The worker vanishes; both trials requeue.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(e.expire_leases(), 2);
        // A 3-trial batch on a fresh worker re-homes both queued trials
        // (original ids and params) and samples one fresh trial.
        let (w2, _) = e.register_worker("n2", "spot", "gpu").unwrap();
        let batch = e.ask_n_as(&ask_body_worker("s", w2), 3, None).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(batch[0].requeued && batch[1].requeued && !batch[2].requeued);
        assert_eq!(batch[0].trial_id, first[0].trial_id);
        assert_eq!(batch[1].trial_id, first[1].trial_id);
        assert_eq!(batch[2].trial_number, 2);
        // Every handout holds exactly one lease slot.
        assert_eq!(e.fleet().lock().leases.len(), 3);
        for r in &batch {
            e.tell(r.trial_id, 1.0).unwrap();
        }
        assert_eq!(e.fleet().lock().leases.len(), 0);
    }

    #[test]
    fn batched_ask_multi_objective() {
        let e = Engine::in_memory(EngineConfig::default());
        let body = parse(
            r#"{
            "study_name": "mo-batch",
            "properties": {"x": {"low": 0.0, "high": 1.0}},
            "direction": ["minimize", "minimize"]
        }"#,
        )
        .unwrap();
        let batch = e.ask_n_as(&body, 3, None).unwrap();
        let numbers: Vec<u64> = batch.iter().map(|r| r.trial_number).collect();
        assert_eq!(numbers, vec![0, 1, 2]);
        for (i, r) in batch.iter().enumerate() {
            e.tell_values(r.trial_id, vec![i as f64, -(i as f64)]).unwrap();
        }
    }
}

//! HTTP service layer: the paper's Table 1 API surface plus the
//! web/monitoring APIs and the embedded dashboard.
//!
//! | API            | Method | Path                        |
//! |----------------|--------|-----------------------------|
//! | version        | GET    | `/api/version`              |
//! | ask            | POST   | `/api/ask/{token}`          |
//! | tell           | POST   | `/api/tell/{token}`         |
//! | should_prune   | POST   | `/api/should_prune/{token}` |
//! | fail           | POST   | `/api/fail/{token}`         |
//! | token issue    | POST   | `/api/token`                |
//! | token revoke   | POST   | `/api/revoke/{token}`       |
//! | worker join    | POST   | `/api/workers/register/{token}`   |
//! | heartbeat      | POST   | `/api/workers/heartbeat/{token}`  |
//! | worker leave   | POST   | `/api/workers/deregister/{token}` |
//! | workers        | GET    | `/api/workers`              |
//! | studies        | GET    | `/api/studies`              |
//! | study          | GET    | `/api/studies/{id}`         |
//! | trials         | GET    | `/api/studies/{id}/trials`  |
//! | best trial     | GET    | `/api/studies/{id}/best`    |
//! | event feed     | GET    | `/api/studies/{id}/events`  |
//! | series         | GET    | `/api/studies/{id}/series`  |
//! | pareto         | GET    | `/api/studies/{id}/pareto`  |
//! | repl log       | GET    | `/api/repl/log`             |
//! | repl snapshot  | GET    | `/api/repl/snapshot`        |
//! | promote        | POST   | `/api/repl/promote`         |
//! | engine stats   | GET    | `/api/stats`                |
//! | metrics        | GET    | `/metrics`                  |
//! | health         | GET    | `/healthz`                  |
//! | dashboard      | GET    | `/`                         |
//!
//! Error envelope is FastAPI's `{"detail": ...}`; auth failures are 401,
//! unknown trials 404, state conflicts 409, malformed bodies 400/422 —
//! the mapping HOPAAS clients are written against.

use super::auth::{Claims, TokenService};
use super::engine::{ApiError, AskReply, Engine, EngineConfig};
use super::replica::{self, HttpTransport, ReplTransport, ReplicaApplier};
use super::trial::TrialState;
use super::views::{self, Cursor, ViewRegistry};
use crate::http::{PathParams, Request, Response, Router, Server, ServerConfig, ServerHandle};
use crate::json::Value;
use crate::store::{Record, ReplFetch};
use crate::sync::MutexExt;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server assembly options.
pub struct HopaasConfig {
    pub engine: EngineConfig,
    pub http: ServerConfig,
    /// Require valid tokens on the Table 1 APIs. Benches may disable.
    pub auth_required: bool,
    /// HMAC secret for tokens.
    pub secret: Vec<u8>,
    /// Storage directory; `None` = in-memory.
    pub data_dir: Option<std::path::PathBuf>,
    /// Upper bound on how long a `GET .../events` long-poll may park
    /// before answering with an empty page (clients may ask for less
    /// via `?timeout=`, never more).
    pub events_poll_timeout: Duration,
    /// Long-poll budget for replication: the cap a primary enforces on
    /// parked `GET /api/repl/log` requests, and the poll window a
    /// follower's applier asks for. New records cut the poll short, so
    /// this only bounds idle-stream latency (and how long a follower
    /// shutdown may wait on an in-flight poll).
    pub repl_poll_timeout: Duration,
}

impl Default for HopaasConfig {
    fn default() -> Self {
        HopaasConfig {
            engine: EngineConfig::default(),
            http: ServerConfig::default(),
            auth_required: true,
            secret: b"hopaas-dev-secret".to_vec(),
            data_dir: None,
            events_poll_timeout: Duration::from_secs(25),
            repl_poll_timeout: Duration::from_secs(2),
        }
    }
}

/// A running HOPAAS service.
pub struct HopaasServer {
    pub engine: Arc<Engine>,
    pub tokens: Arc<TokenService>,
    handle: ServerHandle,
    /// A token issued at startup so single-user setups work immediately
    /// (printed by the CLI; the web flow of the paper is out of scope).
    pub bootstrap_token: String,
    /// Follower-mode stream applier; shared with the
    /// `POST /api/repl/promote` route, which seals it. `None` entries
    /// mean primary mode or an already-promoted follower.
    applier: Arc<Mutex<Option<ReplicaApplier>>>,
}

impl HopaasServer {
    /// Build the engine, router and HTTP server, and start serving. A
    /// follower (`engine.follower` + `engine.primary_url`) first
    /// bootstraps a cold data directory from the primary's snapshot,
    /// then starts the replication applier alongside the HTTP server.
    pub fn start(addr: &str, config: HopaasConfig) -> anyhow::Result<HopaasServer> {
        let mut transport: Option<Box<dyn ReplTransport>> =
            match (config.engine.follower, &config.engine.primary_url) {
                (true, Some(url)) => Some(Box::new(
                    HttpTransport::from_url(url).map_err(|e| anyhow::anyhow!(e))?,
                )),
                _ => None,
            };
        if let (Some(t), Some(dir)) = (transport.as_mut(), &config.data_dir) {
            replica::bootstrap(dir, t.as_mut())
                .map_err(|e| anyhow::anyhow!("replication bootstrap: {e}"))?;
        }
        let engine = Arc::new(match &config.data_dir {
            Some(dir) => Engine::open(dir, config.engine.clone())
                .map_err(|e| anyhow::anyhow!(e.to_string()))?,
            None => Engine::in_memory(config.engine.clone()),
        });
        let tokens = Arc::new(TokenService::new(&config.secret));
        let bootstrap_token = tokens.issue("bootstrap", engine.now(), 365.0 * 86400.0);
        let applier = Arc::new(Mutex::new(transport.map(|t| {
            ReplicaApplier::start(engine.clone(), t, config.repl_poll_timeout)
        })));
        let router = build_router_opts(
            engine.clone(),
            tokens.clone(),
            config.auth_required,
            config.events_poll_timeout,
            ReplRouterState {
                data_dir: config.data_dir.clone(),
                poll_timeout: config.repl_poll_timeout,
                applier: applier.clone(),
            },
        );
        let mut server = Server::bind(addr, router, config.http.clone())?;
        // The view registry's feed signal drives the parked-reader pump:
        // every event append re-polls all parked long-poll connections.
        // The replication source shares the same signal, so parked
        // `/api/repl/log` polls wake on each group commit too.
        server.set_waker(engine.views().signal());
        // Request tracing: the server opens a span (and echoes the
        // X-Request-Id) around every dispatch; stages recorded by the
        // engine underneath land in the same span.
        server.set_tracer(engine.tracer().clone());
        let handle = server.start();
        Ok(HopaasServer { engine, tokens, handle, bootstrap_token, applier })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.handle.addr()
    }

    /// Whether the replication applier is still running (follower mode,
    /// not yet promoted or stalled).
    pub fn replicating(&self) -> bool {
        self.applier.lock_safe().is_some()
    }

    pub fn stop(self) {
        if let Some(a) = self.applier.lock_safe().take() {
            a.seal();
        }
        self.handle.stop();
    }
}

fn err_response(e: &ApiError) -> Response {
    match e {
        ApiError::BadRequest(m) => Response::error(422, m),
        ApiError::NotFound(m) => Response::error(404, m),
        ApiError::Conflict(m) => Response::error(409, m),
        // Quota/fair-share denial: back off and retry.
        ApiError::Quota(m) => Response::error(429, m),
        // Follower refusing a mutation; the body carries the primary's
        // address (when configured) so clients can fail over.
        ApiError::ReadOnly(primary) => {
            let mut o = Value::obj();
            o.set("detail", "read-only follower");
            if let Some(p) = primary {
                o.set("primary", p.as_str());
            }
            Response::json_status(503, &Value::Obj(o))
        }
        ApiError::Storage(m) => Response::error(500, m),
    }
}

/// Parse a request body as JSON or produce the 400 envelope.
fn body_json(req: &Request) -> Result<Value, Response> {
    let text = req
        .body_str()
        .ok_or_else(|| Response::error(400, "body must be utf-8"))?;
    crate::json::parse(text).map_err(|e| Response::error(400, &format!("invalid json: {e}")))
}

/// The wire shape of one suggested trial (shared by the single-ask reply
/// and each element of a batched `{"trials": [...]}` reply).
fn ask_reply_json(reply: AskReply) -> Value {
    let mut o = Value::obj();
    o.set("trial_id", reply.trial_id)
        .set("trial_number", reply.trial_number)
        .set("study_id", reply.study_id)
        .set("study_key", reply.study_key.as_str())
        .set("params", reply.params)
        .set("requeued", reply.requeued);
    Value::Obj(o)
}

/// Parse an optional `limit` query parameter (default 1000). Zero and
/// non-numeric values are the caller's 422.
fn parse_limit(raw: Option<&str>) -> Result<usize, Response> {
    match raw {
        None => Ok(1000),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(Response::error(422, "'limit' must be a positive integer")),
        },
    }
}

/// One page of the replication log on the wire.
fn repl_log_page(records: &[Record], next: u64, primary_next: u64) -> Response {
    let mut o = Value::obj();
    o.set("records", Value::Arr(records.iter().map(Record::to_value).collect()))
        .set("next", next)
        .set("primary_next", primary_next);
    Response::json(&Value::Obj(o))
}

/// 410: the requested cursor fell behind the primary's eviction floor;
/// only a fresh snapshot bootstrap can resync the follower.
fn repl_too_old(oldest: u64) -> Response {
    let mut o = Value::obj();
    o.set("detail", "too old").set("oldest", oldest);
    Response::json_status(410, &Value::Obj(o))
}

/// RAII accounting for parked events readers: increments the waiter
/// gauge when the reader parks, decrements when the deferred poll is
/// dropped — whether it answered, timed out, or the connection died.
struct WaiterGuard {
    views: Arc<ViewRegistry>,
}

impl WaiterGuard {
    fn new(views: Arc<ViewRegistry>) -> WaiterGuard {
        views.waiter_delta(1);
        WaiterGuard { views }
    }
}

impl Drop for WaiterGuard {
    fn drop(&mut self) {
        self.views.waiter_delta(-1);
    }
}

/// Replication wiring handed to the router: the data directory served
/// by `GET /api/repl/snapshot`, the long-poll cap for
/// `GET /api/repl/log`, and the applier handle that
/// `POST /api/repl/promote` seals before flipping the engine writable.
pub struct ReplRouterState {
    pub data_dir: Option<std::path::PathBuf>,
    pub poll_timeout: Duration,
    pub applier: Arc<Mutex<Option<ReplicaApplier>>>,
}

impl Default for ReplRouterState {
    fn default() -> Self {
        ReplRouterState {
            data_dir: None,
            poll_timeout: Duration::from_secs(2),
            applier: Arc::new(Mutex::new(None)),
        }
    }
}

/// Assemble the full router with default read-path options. Exposed for
/// in-process benches (no TCP).
pub fn build_router(
    engine: Arc<Engine>,
    tokens: Arc<TokenService>,
    auth_required: bool,
) -> Router {
    build_router_opts(
        engine,
        tokens,
        auth_required,
        Duration::from_secs(25),
        ReplRouterState::default(),
    )
}

/// Assemble the full router.
pub fn build_router_opts(
    engine: Arc<Engine>,
    tokens: Arc<TokenService>,
    auth_required: bool,
    events_poll_timeout: Duration,
    repl: ReplRouterState,
) -> Router {
    let mut router = Router::new();

    // --- version -------------------------------------------------------
    router.get("/api/version", |_, _| {
        let mut o = Value::obj();
        o.set("version", crate::VERSION).set("service", "hopaas");
        Response::json(&Value::Obj(o))
    });

    router.get("/healthz", |_, _| Response::text("ok"));

    // --- auth helpers ---------------------------------------------------
    // One validation path for every route: `authenticate` yields the
    // caller's claims (None with auth disabled) or the 401; `check` is
    // the validity-only view most routes use.
    let authenticate = {
        let tokens = tokens.clone();
        let engine = engine.clone();
        move |params: &PathParams| -> Result<Option<Claims>, Response> {
            if !auth_required {
                return Ok(None);
            }
            let tok = params.get("token").unwrap_or("");
            match tokens.validate(tok, engine.now()) {
                Ok(claims) => Ok(Some(claims)),
                Err(e) => {
                    engine.metrics.auth_failures.inc();
                    Err(Response::error(401, &e.to_string()))
                }
            }
        }
    };
    let check = {
        let authenticate = authenticate.clone();
        move |params: &PathParams| -> Option<Response> { authenticate(params).err() }
    };

    // --- ask -------------------------------------------------------------
    {
        let engine = engine.clone();
        let authenticate = authenticate.clone();
        router.post("/api/ask/{token}", move |req, params| {
            // The ask is the one API that needs the caller's *identity*,
            // not just validity: per-tenant quotas key on the token's
            // user claim. With auth disabled (dev/benches) an explicit
            // "tenant" body field stands in; with auth on, the token is
            // authoritative and the body field is ignored.
            let claims = match authenticate(params) {
                Ok(c) => c,
                Err(resp) => return resp,
            };
            let t0 = Instant::now();
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let tenant: Option<String> = match &claims {
                Some(c) => c.tenant().map(str::to_string),
                None => body.get("tenant").as_str().map(str::to_string),
            };
            // Batched ask: `"n": k` in the body reserves k trials in one
            // call (one admission pass, one sampler fit). The reply is
            // `{"trials": [...]}` iff the request carried "n" — bare
            // asks keep the legacy single-object shape.
            let n = match body.get("n") {
                Value::Null => None,
                v => match v.as_u64() {
                    Some(k) => Some(k as usize),
                    None => return Response::error(422, "'n' must be a positive integer"),
                },
            };
            let result = engine.ask_n_as(&body, n.unwrap_or(1), tenant.as_deref());
            engine
                .metrics
                .ask_latency
                .observe(t0.elapsed().as_secs_f64());
            match result {
                Ok(replies) => match n {
                    Some(_) => {
                        let trials: Vec<Value> =
                            replies.into_iter().map(ask_reply_json).collect();
                        let mut o = Value::obj();
                        o.set("trials", Value::Arr(trials));
                        Response::json(&Value::Obj(o))
                    }
                    None => {
                        let reply = replies.into_iter().next().expect("n=1 yields one reply");
                        Response::json(&ask_reply_json(reply))
                    }
                },
                Err(e) => err_response(&e),
            }
        });
    }

    // --- tell --------------------------------------------------------------
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/tell/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let t0 = Instant::now();
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let Some(trial_id) = body.get("trial_id").as_u64() else {
                return Response::error(422, "missing 'trial_id'");
            };
            // Multi-objective: "values" array (paper §5 future work).
            if let Some(vals) = body.get("values").as_arr() {
                let values: Vec<f64> = vals.iter().filter_map(Value::as_f64).collect();
                if values.len() != vals.len() {
                    return Response::error(422, "'values' must be numeric");
                }
                let result = engine.tell_values(trial_id, values);
                engine
                    .metrics
                    .tell_latency
                    .observe(t0.elapsed().as_secs_f64());
                return match result {
                    Ok((study_id, on_front)) => {
                        let mut o = Value::obj();
                        o.set("trial_id", trial_id)
                            .set("study_id", study_id)
                            .set("state", "completed")
                            .set("on_pareto_front", on_front);
                        Response::json(&Value::Obj(o))
                    }
                    Err(e) => err_response(&e),
                };
            }
            // Accept "value", "score" or "loss" — client dialects.
            let value = body
                .get("value")
                .as_f64()
                .or_else(|| body.get("score").as_f64())
                .or_else(|| body.get("loss").as_f64());
            let Some(value) = value else {
                return Response::error(422, "missing numeric 'value'");
            };
            let result = engine.tell(trial_id, value);
            engine
                .metrics
                .tell_latency
                .observe(t0.elapsed().as_secs_f64());
            match result {
                Ok((study_id, is_best)) => {
                    let mut o = Value::obj();
                    o.set("trial_id", trial_id)
                        .set("study_id", study_id)
                        .set("state", "completed")
                        .set("is_best", is_best);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }

    // --- should_prune ---------------------------------------------------
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/should_prune/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let t0 = Instant::now();
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let (Some(trial_id), Some(step), Some(value)) = (
                body.get("trial_id").as_u64(),
                body.get("step").as_u64(),
                body.get("value")
                    .as_f64()
                    .or_else(|| body.get("loss").as_f64()),
            ) else {
                return Response::error(422, "need 'trial_id', 'step', numeric 'value'");
            };
            let result = engine.should_prune(trial_id, step, value);
            engine
                .metrics
                .should_prune_latency
                .observe(t0.elapsed().as_secs_f64());
            match result {
                Ok(prune) => {
                    let mut o = Value::obj();
                    o.set("trial_id", trial_id).set("should_prune", prune);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }

    // --- fail -------------------------------------------------------------
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/fail/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let Some(trial_id) = body.get("trial_id").as_u64() else {
                return Response::error(422, "missing 'trial_id'");
            };
            match engine.fail(trial_id) {
                Ok(()) => {
                    let mut o = Value::obj();
                    o.set("trial_id", trial_id).set("state", "failed");
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }

    // --- fleet: worker registry + heartbeat leases -------------------------
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/workers/register/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let name = body.get("name").as_str().unwrap_or("anonymous");
            let site = body.get("site").as_str().unwrap_or("default");
            let gpu = body.get("gpu").as_str().unwrap_or("");
            match engine.register_worker(name, site, gpu) {
                Ok((worker_id, lease_timeout)) => {
                    let mut o = Value::obj();
                    o.set("worker_id", worker_id)
                        .set("lease_timeout", lease_timeout)
                        // Heartbeat at a third of the lease so two
                        // missed beats still keep the lease alive.
                        .set("heartbeat_every", lease_timeout.map(|t| t / 3.0));
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/workers/heartbeat/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let Some(worker_id) = body.get("worker_id").as_u64() else {
                return Response::error(422, "missing 'worker_id'");
            };
            match engine.worker_heartbeat(worker_id) {
                Ok(leases) => {
                    let mut o = Value::obj();
                    o.set("worker_id", worker_id).set("leases", leases);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }
    {
        let engine = engine.clone();
        let check = check.clone();
        router.post("/api/workers/deregister/{token}", move |req, params| {
            if let Some(resp) = check(params) {
                return resp;
            }
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let Some(worker_id) = body.get("worker_id").as_u64() else {
                return Response::error(422, "missing 'worker_id'");
            };
            match engine.deregister_worker(worker_id) {
                Ok(requeued) => {
                    let mut o = Value::obj();
                    o.set("worker_id", worker_id).set("requeued", requeued);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/workers", move |_, _| Response::json(&engine.workers_json()));
    }

    // --- token management -------------------------------------------------
    {
        let tokens = tokens.clone();
        let engine = engine.clone();
        router.post("/api/token", move |req, _| {
            let body = match body_json(req) {
                Ok(b) => b,
                Err(r) => return r,
            };
            let user = body.get("user").as_str().unwrap_or("anonymous");
            let ttl = body.get("ttl").as_f64().unwrap_or(86400.0);
            let tok = tokens.issue(user, engine.now(), ttl);
            let mut o = Value::obj();
            o.set("token", tok).set("user", user).set("ttl", ttl);
            Response::json(&Value::Obj(o))
        });
    }
    {
        let tokens = tokens.clone();
        let engine = engine.clone();
        router.post("/api/revoke/{token}", move |_, params| {
            let tok = params.get("token").unwrap_or("");
            match tokens.validate(tok, engine.now()) {
                Ok(claims) => {
                    tokens.revoke(claims.uid);
                    let mut o = Value::obj();
                    o.set("revoked", claims.uid);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => Response::error(401, &e.to_string()),
            }
        });
    }

    // --- web data APIs (dashboard feeds, paper §3) -------------------------
    //
    // The list/detail GETs come in two flavors. Paramless calls keep the
    // legacy bare-array shapes (rendered from engine state, one shard
    // lock at a time). Calls carrying `limit`/`cursor`/`state` switch to
    // the materialized-view read path: cursor-paginated envelopes served
    // from epoch-stamped snapshots, never touching a shard lock.
    {
        let engine = engine.clone();
        router.get("/api/studies", move |req, _| {
            let limit = req.query_param("limit");
            let cursor = req.query_param("cursor");
            if limit.is_none() && cursor.is_none() {
                return Response::json(&engine.studies_json());
            }
            let limit = match parse_limit(limit.as_deref()) {
                Ok(n) => n,
                Err(r) => return r,
            };
            let after_id = match cursor.as_deref() {
                None => None,
                Some(s) => match s.parse::<u64>() {
                    Ok(id) => Some(id),
                    Err(_) => {
                        return Response::error(422, &format!("malformed cursor '{s}'"))
                    }
                },
            };
            let snapshots = engine.views().study_views();
            Response::json_raw(views::render_studies_page(&snapshots, after_id, limit))
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/studies/{id}", move |_, params| {
            match params.get("id").and_then(|s| s.parse().ok()).and_then(|id| engine.study_json(id)) {
                Some(v) => Response::json(&v),
                None => Response::error(404, "unknown study"),
            }
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/studies/{id}/trials", move |req, params| {
            let Some(id) = params.get("id").and_then(|s| s.parse::<u64>().ok()) else {
                return Response::error(404, "unknown study");
            };
            let limit = req.query_param("limit");
            let cursor = req.query_param("cursor");
            let state = req.query_param("state");
            if limit.is_none() && cursor.is_none() && state.is_none() {
                return match engine.trials_json(id) {
                    Some(v) => Response::json(&v),
                    None => Response::error(404, "unknown study"),
                };
            }
            let Some(view) = engine.views().study_view(id) else {
                return Response::error(404, "unknown study");
            };
            let limit = match parse_limit(limit.as_deref()) {
                Ok(n) => n,
                Err(r) => return r,
            };
            let cursor = match cursor.as_deref() {
                None => Cursor { epoch: view.epoch, index: 0 },
                Some(s) => match Cursor::decode(s) {
                    Ok(c) => c,
                    Err(m) => return Response::error(422, &m),
                },
            };
            let state = match state.as_deref() {
                None => None,
                Some("running") => Some(TrialState::Running),
                Some("completed") => Some(TrialState::Completed),
                Some("pruned") => Some(TrialState::Pruned),
                Some("failed") => Some(TrialState::Failed),
                Some(s) => return Response::error(422, &format!("unknown state '{s}'")),
            };
            Response::json_raw(views::render_trials_page(&view, cursor, limit, state))
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/studies/{id}/best", move |_, params| {
            match params
                .get("id")
                .and_then(|s| s.parse().ok())
                .and_then(|id| engine.views().study_view(id))
            {
                Some(view) => Response::json_raw(views::render_best_page(&view)),
                None => Response::error(404, "unknown study"),
            }
        });
    }
    {
        // Live trial feed: `?since=N` replays events with seq > N, then
        // long-polls. When the watermark is already past `since` the
        // reply is immediate; otherwise the connection parks on the
        // server's reader pump (no worker thread held) until the feed
        // signal fires or the poll window closes with an empty page.
        let engine = engine.clone();
        router.get("/api/studies/{id}/events", move |req, params| {
            let Some(id) = params.get("id").and_then(|s| s.parse::<u64>().ok()) else {
                return Response::error(404, "unknown study");
            };
            let since = match req.query_param("since").as_deref() {
                None => 0u64,
                Some(s) => match s.parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return Response::error(
                            422,
                            "'since' must be a non-negative integer",
                        )
                    }
                },
            };
            let limit = match parse_limit(req.query_param("limit").as_deref()) {
                Ok(n) => n,
                Err(r) => return r,
            };
            let timeout = match req.query_param("timeout").as_deref() {
                None => events_poll_timeout,
                Some(s) => match s.parse::<f64>() {
                    Ok(t) if t.is_finite() && t >= 0.0 => {
                        Duration::from_secs_f64(t.min(events_poll_timeout.as_secs_f64()))
                    }
                    _ => {
                        return Response::error(
                            422,
                            "'timeout' must be a non-negative number",
                        )
                    }
                },
            };
            let Some(page) = engine.views().events_after(id, since, limit) else {
                return Response::error(404, "unknown study");
            };
            if page.watermark > since || timeout.is_zero() {
                return Response::json_raw(views::render_events_page(id, &page));
            }
            let registry = engine.views().clone();
            let guard = WaiterGuard::new(registry.clone());
            let deadline = Instant::now() + timeout;
            Response::deferred(deadline, move |due| {
                let _parked = &guard;
                match registry.events_after(id, since, limit) {
                    Some(p) if p.watermark > since || due => {
                        Some(Response::json_raw(views::render_events_page(id, &p)))
                    }
                    None => Some(Response::error(404, "unknown study")),
                    _ => None,
                }
            })
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/studies/{id}/pareto", move |_, params| {
            match params.get("id").and_then(|s| s.parse().ok()).and_then(|id| engine.pareto_json(id)) {
                Some(v) => Response::json(&v),
                None => Response::error(404, "unknown study"),
            }
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/studies/{id}/series", move |_, params| {
            match params.get("id").and_then(|s| s.parse().ok()).and_then(|id| engine.series_json(id)) {
                Some(v) => Response::json(&v),
                None => Response::error(404, "unknown study"),
            }
        });
    }

    // --- replication ------------------------------------------------------
    {
        // The primary's acknowledged WAL stream. Followers poll with
        // their resume cursor; a cursor at the head parks the
        // connection on the reader pump (the replication source fires
        // the same signal as the view feeds) until the next group
        // commit publishes records or the poll window closes with an
        // empty page.
        let engine = engine.clone();
        let poll_cap = repl.poll_timeout;
        router.get("/api/repl/log", move |req, _| {
            let from = match req.query_param("from").as_deref() {
                None => 0u64,
                Some(s) => match s.parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return Response::error(422, "'from' must be a non-negative integer")
                    }
                },
            };
            let max = match req.query_param("max").as_deref() {
                None => 4096usize,
                Some(s) => match s.parse::<usize>() {
                    Ok(n) if n >= 1 => n,
                    _ => return Response::error(422, "'max' must be a positive integer"),
                },
            };
            let timeout = match req.query_param("timeout_ms").as_deref() {
                None => Duration::ZERO,
                Some(s) => match s.parse::<u64>() {
                    Ok(ms) => Duration::from_millis(ms).min(poll_cap),
                    Err(_) => {
                        return Response::error(
                            422,
                            "'timeout_ms' must be a non-negative integer",
                        )
                    }
                },
            };
            let Some(source) = engine.repl_source() else {
                return Response::error(404, "replication log unavailable on this node");
            };
            let t0 = Instant::now();
            let first = source.fetch(from, max);
            crate::obs::stage(crate::obs::Stage::ReplFetch, t0.elapsed());
            match first {
                ReplFetch::Batches { records, next, primary_next } => {
                    repl_log_page(&records, next, primary_next)
                }
                ReplFetch::TooOld { oldest } => repl_too_old(oldest),
                ReplFetch::UpToDate { next } if timeout.is_zero() => {
                    repl_log_page(&[], from, next)
                }
                ReplFetch::UpToDate { .. } => {
                    let deadline = Instant::now() + timeout;
                    Response::deferred(deadline, move |due| match source.fetch(from, max) {
                        ReplFetch::Batches { records, next, primary_next } => {
                            Some(repl_log_page(&records, next, primary_next))
                        }
                        ReplFetch::TooOld { oldest } => Some(repl_too_old(oldest)),
                        ReplFetch::UpToDate { next } if due => {
                            Some(repl_log_page(&[], from, next))
                        }
                        ReplFetch::UpToDate { .. } => None,
                    })
                }
            }
        });
    }
    {
        // Current snapshot bundle (manifest + segment files) for cold
        // follower bootstrap.
        let data_dir = repl.data_dir.clone();
        router.get("/api/repl/snapshot", move |_, _| match &data_dir {
            None => Response::error(404, "no durable storage to snapshot"),
            Some(dir) => match crate::store::read_snapshot_bundle(dir) {
                Ok(bundle) => Response::json(&bundle),
                Err(e) => Response::error(500, &e.to_string()),
            },
        });
    }
    {
        // Promote this follower: seal the applier (drains the residual
        // acknowledged tail), then flip the engine writable exactly
        // once. 409 when the node is already a primary.
        let engine = engine.clone();
        let applier = repl.applier.clone();
        router.post("/api/repl/promote", move |_, _| {
            if let Some(a) = applier.lock_safe().take() {
                a.seal();
            }
            match engine.promote() {
                Ok(next) => {
                    let mut o = Value::obj();
                    o.set("role", "primary").set("writable", true).set("next", next);
                    Response::json(&Value::Obj(o))
                }
                Err(e) => err_response(&e),
            }
        });
    }

    // --- engine statistics (shards, group-commit batching) ----------------
    {
        let engine = engine.clone();
        router.get("/api/stats", move |_, _| Response::json(&engine.stats_json()));
    }

    // --- request traces ----------------------------------------------------
    // Registered before `/api/trace/{id}`: first match wins, so the
    // literal `recent` segment is never captured as an id.
    {
        let engine = engine.clone();
        router.get("/api/trace/recent", move |req, _| {
            let limit = match parse_limit(req.query_param("limit").as_deref()) {
                Ok(n) => n,
                Err(r) => return r,
            };
            let kind = match req.query_param("kind").as_deref() {
                None => None,
                Some(s) => match crate::obs::OpKind::parse(s) {
                    Some(k) => Some(k),
                    None => {
                        return Response::error(
                            422,
                            &format!("unknown kind '{s}' (ask|tell|prune|fail|read|other)"),
                        )
                    }
                },
            };
            let study = match req.query_param("study").as_deref() {
                None => None,
                Some(s) => match s.parse::<u64>() {
                    Ok(id) => Some(id),
                    Err(_) => return Response::error(422, "'study' must be an integer id"),
                },
            };
            Response::json(&engine.tracer().recent(limit, kind, study))
        });
    }
    {
        let engine = engine.clone();
        router.get("/api/trace/{id}", move |_, params| {
            let id = params.get("id").unwrap_or("");
            match engine.tracer().get(id) {
                Some(v) => Response::json(&v),
                None => Response::error(404, "unknown or evicted trace id"),
            }
        });
    }

    // --- metrics + dashboard ----------------------------------------------
    {
        let engine = engine.clone();
        router.get("/metrics", move |_, _| Response::text(&engine.render_metrics()));
    }
    router.get("/", |_, _| Response::html(DASHBOARD_HTML));

    router
}

/// Minimal single-page dashboard: fetches the web data APIs at regular
/// intervals and renders study tables + loss curves on a canvas — the
/// role Chartist plays in the paper's web UI.
const DASHBOARD_HTML: &str = r#"<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>HOPAAS</title>
<style>
body{font-family:system-ui,sans-serif;margin:2rem;background:#10141a;color:#dde}
h1{font-size:1.4rem} h2{font-size:1.1rem;margin-top:1.5rem}
table{border-collapse:collapse;width:100%;font-size:0.9rem}
td,th{border-bottom:1px solid #334;padding:0.3rem 0.6rem;text-align:left}
tr:hover{background:#1a2030} .best{color:#7f7} .state-pruned{color:#fa5}
.state-running{color:#7af} .state-failed{color:#f66}
canvas{background:#0a0d12;border:1px solid #334;margin-top:0.5rem}
</style></head><body>
<h1>HOPAAS &mdash; Hyperparameter Optimization As A Service</h1>
<div id="studies"></div>
<h2>Loss curves <span id="which"></span></h2>
<canvas id="chart" width="900" height="300"></canvas>
<script>
let current = null;
async function refresh() {
  const studies = await (await fetch('/api/studies')).json();
  const el = document.getElementById('studies');
  el.innerHTML = '<table><tr><th>id</th><th>name</th><th>direction</th>'+
    '<th>sampler</th><th>trials</th><th>running</th><th>completed</th>'+
    '<th>pruned</th><th>best</th></tr>' + studies.map(s =>
    `<tr onclick="current=${s.id};refresh()"><td>${s.id}</td><td>${s.name}</td>`+
    `<td>${s.direction}</td><td>${s.sampler.name}</td><td>${s.n_trials}</td>`+
    `<td>${s.n_running}</td><td>${s.n_completed}</td><td>${s.n_pruned}</td>`+
    `<td class="best">${s.best_value==null?'—':s.best_value.toPrecision(5)}</td></tr>`
  ).join('') + '</table>';
  if (current==null && studies.length) current = studies[0].id;
  if (current!=null) drawSeries(current);
}
async function drawSeries(id) {
  document.getElementById('which').textContent = '(study '+id+')';
  const series = await (await fetch('/api/studies/'+id+'/series')).json();
  const c = document.getElementById('chart'), g = c.getContext('2d');
  g.clearRect(0,0,c.width,c.height);
  let xs=[], ys=[];
  for (const t of series) for (const p of t.points) { xs.push(p[0]); ys.push(p[1]); }
  if (!xs.length) return;
  const xmax=Math.max(...xs), ymin=Math.min(...ys), ymax=Math.max(...ys);
  const X=x=>20+(c.width-40)*x/Math.max(xmax,1);
  const Y=y=>c.height-20-(c.height-40)*(y-ymin)/Math.max(ymax-ymin,1e-12);
  const colors=['#7af','#7f7','#fa5','#f6f','#ff6','#6ff','#f66','#aaf'];
  series.forEach((t,i)=>{ if(!t.points.length) return;
    g.strokeStyle=colors[i%colors.length]; g.beginPath();
    t.points.forEach((p,j)=>{ j?g.lineTo(X(p[0]),Y(p[1])):g.moveTo(X(p[0]),Y(p[1])); });
    g.stroke(); });
}
refresh(); setInterval(refresh, 2000);
</script></body></html>"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::Client;
    use crate::json::parse;

    fn server(auth: bool) -> HopaasServer {
        let config = HopaasConfig { auth_required: auth, ..Default::default() };
        HopaasServer::start("127.0.0.1:0", config).unwrap()
    }

    fn ask_body() -> Value {
        parse(
            r#"{"study_name": "t", "properties": {"x": {"low": 0.0, "high": 1.0}},
             "sampler": {"name": "random"}}"#,
        )
        .unwrap()
    }

    #[test]
    fn version_endpoint() {
        let s = server(false);
        let mut c = Client::connect(s.addr()).unwrap();
        let v = c.get("/api/version").unwrap().json_body().unwrap();
        assert_eq!(v.get("version").as_str(), Some(crate::VERSION));
        s.stop();
    }

    #[test]
    fn full_workflow_over_http() {
        let s = server(true);
        let tok = s.bootstrap_token.clone();
        let mut c = Client::connect(s.addr()).unwrap();

        let r = c
            .post_json(&format!("/api/ask/{tok}"), &ask_body())
            .unwrap();
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        let ask = r.json_body().unwrap();
        let trial_id = ask.get("trial_id").as_u64().unwrap();
        assert!(ask.get("params").get("x").as_f64().is_some());

        let mut rep = Value::obj();
        rep.set("trial_id", trial_id).set("step", 1u64).set("value", 0.5);
        let pr = c
            .post_json(&format!("/api/should_prune/{tok}"), &Value::Obj(rep))
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(pr.get("should_prune").as_bool(), Some(false));

        let mut tell = Value::obj();
        tell.set("trial_id", trial_id).set("value", 0.3);
        let tr = c
            .post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell))
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(tr.get("state").as_str(), Some("completed"));
        assert_eq!(tr.get("is_best").as_bool(), Some(true));
        s.stop();
    }

    #[test]
    fn auth_rejected_without_valid_token() {
        let s = server(true);
        let mut c = Client::connect(s.addr()).unwrap();
        let r = c.post_json("/api/ask/garbage", &ask_body()).unwrap();
        assert_eq!(r.status, 401);
        // Issue a token via the API, then it works.
        let mut req = Value::obj();
        req.set("user", "u1").set("ttl", 60.0);
        let tok = c
            .post_json("/api/token", &Value::Obj(req))
            .unwrap()
            .json_body()
            .unwrap();
        let tok = tok.get("token").as_str().unwrap().to_string();
        let r2 = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
        assert_eq!(r2.status, 200);
        s.stop();
    }

    #[test]
    fn revoked_token_stops_working() {
        let s = server(true);
        let tok = s.bootstrap_token.clone();
        let mut c = Client::connect(s.addr()).unwrap();
        let r = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
        assert_eq!(r.status, 200);
        let rv = c.post(&format!("/api/revoke/{tok}"), b"{}").unwrap();
        assert_eq!(rv.status, 200);
        let r2 = c.post_json(&format!("/api/ask/{tok}"), &ask_body()).unwrap();
        assert_eq!(r2.status, 401);
        s.stop();
    }

    #[test]
    fn error_mapping() {
        let s = server(false);
        let mut c = Client::connect(s.addr()).unwrap();
        // 400: bad json
        let r = c.post("/api/ask/x", b"{not json").unwrap();
        assert_eq!(r.status, 400);
        // 422: missing fields
        let r = c.post("/api/tell/x", b"{}").unwrap();
        assert_eq!(r.status, 422);
        // 404: unknown trial
        let mut tell = Value::obj();
        tell.set("trial_id", 12345u64).set("value", 1.0);
        let r = c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap();
        assert_eq!(r.status, 404);
        // 409: double tell
        let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
        let id = ask.get("trial_id").as_u64().unwrap();
        let mut tell = Value::obj();
        tell.set("trial_id", id).set("value", 1.0);
        assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell.clone())).unwrap().status, 200);
        assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap().status, 409);
        // 404: unknown route; 405: wrong method
        assert_eq!(c.get("/api/nope").unwrap().status, 404);
        assert_eq!(c.get("/api/ask/x").unwrap().status, 405);
        s.stop();
    }

    #[test]
    fn fleet_worker_endpoints() {
        let s = server(false);
        let mut c = Client::connect(s.addr()).unwrap();
        let mut reg = Value::obj();
        reg.set("name", "node-1").set("site", "infn-cloud").set("gpu", "a100");
        let r = c
            .post_json("/api/workers/register/x", &Value::Obj(reg))
            .unwrap()
            .json_body()
            .unwrap();
        let wid = r.get("worker_id").as_u64().unwrap();
        assert!(r.get("lease_timeout").as_f64().is_some());
        let hb = r.get("heartbeat_every").as_f64().unwrap();
        assert!(hb < r.get("lease_timeout").as_f64().unwrap());

        // A worker-bound ask binds a lease; the reply carries `requeued`.
        let mut body = ask_body();
        if let Value::Obj(o) = &mut body {
            o.set("worker", wid);
        }
        let ask = c.post_json("/api/ask/x", &body).unwrap().json_body().unwrap();
        assert_eq!(ask.get("requeued").as_bool(), Some(false));
        let trial_id = ask.get("trial_id").as_u64().unwrap();

        let mut hb = Value::obj();
        hb.set("worker_id", wid);
        let h = c
            .post_json("/api/workers/heartbeat/x", &Value::Obj(hb.clone()))
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(h.get("leases").as_u64(), Some(1));

        let workers = c.get("/api/workers").unwrap().json_body().unwrap();
        assert_eq!(workers.at(0).get("site").as_str(), Some("infn-cloud"));
        assert_eq!(workers.at(0).get("state").as_str(), Some("alive"));

        let stats = c.get("/api/stats").unwrap().json_body().unwrap();
        assert_eq!(stats.get("fleet").get("leases").as_u64(), Some(1));
        assert_eq!(stats.get("fleet").get("workers_alive").as_u64(), Some(1));

        // Telling the trial releases the lease.
        let mut tell = Value::obj();
        tell.set("trial_id", trial_id).set("value", 0.5);
        assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap().status, 200);
        let h2 = c
            .post_json("/api/workers/heartbeat/x", &Value::Obj(hb))
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(h2.get("leases").as_u64(), Some(0));

        // Unknown worker ids: 404 on heartbeat/deregister; asks bound
        // to them are rejected before any trial is created.
        let mut bogus = Value::obj();
        bogus.set("worker_id", 999u64);
        let resp = c
            .post_json("/api/workers/heartbeat/x", &Value::Obj(bogus.clone()))
            .unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(
            c.post_json("/api/workers/deregister/x", &Value::Obj(bogus)).unwrap().status,
            404
        );
        let mut body = ask_body();
        if let Value::Obj(o) = &mut body {
            o.set("worker", 999u64);
        }
        assert_eq!(c.post_json("/api/ask/x", &body).unwrap().status, 404);

        // Graceful deregister; the metrics render the fleet series.
        let mut dereg = Value::obj();
        dereg.set("worker_id", wid);
        let d = c
            .post_json("/api/workers/deregister/x", &Value::Obj(dereg))
            .unwrap()
            .json_body()
            .unwrap();
        assert_eq!(d.get("requeued").as_u64(), Some(0));
        let metrics = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        assert!(metrics.contains("hopaas_fleet_workers_registered_total 1"));
        s.stop();
    }

    #[test]
    fn tenant_quota_denial_carries_attribution_over_http() {
        let config = HopaasConfig {
            auth_required: true,
            engine: EngineConfig { tenant_quota: 1, ..Default::default() },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let mut c = Client::connect(s.addr()).unwrap();
        // Mint a token for alice: its user claim is the tenant key.
        let mut req = Value::obj();
        req.set("user", "alice").set("ttl", 3600.0);
        let tok = c
            .post_json("/api/token", &Value::Obj(req))
            .unwrap()
            .json_body()
            .unwrap();
        let tok = tok.get("token").as_str().unwrap().to_string();
        let mut reg = Value::obj();
        reg.set("name", "n1").set("site", "cloud").set("gpu", "a100");
        let r = c
            .post_json(&format!("/api/workers/register/{tok}"), &Value::Obj(reg))
            .unwrap()
            .json_body()
            .unwrap();
        let wid = r.get("worker_id").as_u64().unwrap();
        let mut body = ask_body();
        if let Value::Obj(o) = &mut body {
            o.set("worker", wid);
        }
        let ok = c.post_json(&format!("/api/ask/{tok}"), &body).unwrap();
        assert_eq!(ok.status, 200, "{:?}", String::from_utf8_lossy(&ok.body));
        let trial_id = ok.json_body().unwrap().get("trial_id").as_u64().unwrap();
        // One lease held, tenant quota 1: the next ask is denied with
        // the tenant named in the 429 detail.
        let denied = c.post_json(&format!("/api/ask/{tok}"), &body).unwrap();
        assert_eq!(denied.status, 429);
        let detail = denied
            .json_body()
            .unwrap()
            .get("detail")
            .as_str()
            .unwrap()
            .to_string();
        assert!(detail.contains("tenant 'alice'"), "{detail}");
        // The stats tenants block and the labeled metrics agree.
        let stats = c.get("/api/stats").unwrap().json_body().unwrap();
        let tenants = stats.get("fleet").get("tenants");
        assert_eq!(tenants.at(0).get("tenant").as_str(), Some("alice"));
        assert_eq!(tenants.at(0).get("active").as_u64(), Some(1));
        assert_eq!(tenants.at(0).get("quota").as_u64(), Some(1));
        let metrics = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        assert!(
            metrics.contains("hopaas_tenant_quota_denials_total{tenant=\"alice\"} 1"),
            "{metrics}"
        );
        assert!(metrics.contains("hopaas_tenant_leases{tenant=\"alice\"} 1"), "{metrics}");
        // Finishing the trial frees the tenant's budget.
        let mut tell = Value::obj();
        tell.set("trial_id", trial_id).set("value", 1.0);
        assert_eq!(
            c.post_json(&format!("/api/tell/{tok}"), &Value::Obj(tell)).unwrap().status,
            200
        );
        assert_eq!(c.post_json(&format!("/api/ask/{tok}"), &body).unwrap().status, 200);
        s.stop();
    }

    #[test]
    fn worker_less_ask_rate_denial_over_http() {
        // Legacy (worker-less) clients never hold leases, so only the
        // sliding ask-rate ledger bounds them. On a --no-auth server the
        // body "tenant" field stands in for the token claim.
        let config = HopaasConfig {
            auth_required: false,
            engine: EngineConfig {
                tenant_ask_rate: 1,
                tenant_ask_window: 3600.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = HopaasServer::start("127.0.0.1:0", config).unwrap();
        let mut c = Client::connect(s.addr()).unwrap();
        let mut body = ask_body();
        if let Value::Obj(o) = &mut body {
            o.set("tenant", "alice");
        }
        assert_eq!(c.post_json("/api/ask/x", &body).unwrap().status, 200);
        let denied = c.post_json("/api/ask/x", &body).unwrap();
        assert_eq!(denied.status, 429);
        let detail = denied
            .json_body()
            .unwrap()
            .get("detail")
            .as_str()
            .unwrap()
            .to_string();
        assert!(detail.contains("tenant 'alice'"), "{detail}");
        assert!(detail.contains("ask rate"), "{detail}");
        // Tenant-less legacy asks stay unlimited.
        assert_eq!(c.post_json("/api/ask/x", &ask_body()).unwrap().status, 200);
        let metrics = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
        assert!(
            metrics.contains("hopaas_tenant_quota_denials_total{tenant=\"alice\"} 1"),
            "{metrics}"
        );
        // The stats policy block reports the knobs being enforced.
        let stats = c.get("/api/stats").unwrap().json_body().unwrap();
        let policy = stats.get("fleet").get("policy");
        assert_eq!(policy.get("tenant_ask_rate").as_u64(), Some(1));
        assert_eq!(policy.get("tenant_ask_window").as_f64(), Some(3600.0));
        s.stop();
    }

    #[test]
    fn web_data_apis() {
        let s = server(false);
        let mut c = Client::connect(s.addr()).unwrap();
        let ask = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
        let sid = ask.get("study_id").as_u64().unwrap();
        let id = ask.get("trial_id").as_u64().unwrap();
        let mut rep = Value::obj();
        rep.set("trial_id", id).set("step", 1u64).set("value", 2.0);
        c.post_json("/api/should_prune/x", &Value::Obj(rep)).unwrap();

        let studies = c.get("/api/studies").unwrap().json_body().unwrap();
        assert_eq!(studies.at(0).get("id").as_u64(), Some(sid));
        let trials = c.get(&format!("/api/studies/{sid}/trials")).unwrap().json_body().unwrap();
        assert_eq!(trials.as_arr().unwrap().len(), 1);
        let series = c.get(&format!("/api/studies/{sid}/series")).unwrap().json_body().unwrap();
        assert_eq!(series.at(0).get("points").at(0).at(1).as_f64(), Some(2.0));
        assert_eq!(c.get("/api/studies/99").unwrap().status, 404);

        let stats = c.get("/api/stats").unwrap().json_body().unwrap();
        assert_eq!(stats.get("shards").as_u64(), Some(8));
        assert_eq!(stats.get("studies").as_u64(), Some(1));
        assert_eq!(stats.get("durable").as_bool(), Some(false));

        let metrics = c.get("/metrics").unwrap();
        let text = String::from_utf8(metrics.body).unwrap();
        assert!(text.contains("hopaas_ask_total 1"));
        assert!(text.contains("hopaas_engine_shards 8"));
        assert!(text.contains("hopaas_shard_ops_total{shard=\"0\"}"));
        let dash = c.get("/").unwrap();
        assert_eq!(dash.status, 200);
        assert!(String::from_utf8(dash.body).unwrap().contains("HOPAAS"));
        s.stop();
    }

    #[test]
    fn batched_ask_over_http() {
        let s = server(false);
        let mut c = Client::connect(s.addr()).unwrap();
        // "n" in the body switches the reply to the {"trials": [...]}
        // shape, one element per suggestion.
        let mut body = ask_body();
        if let Value::Obj(o) = &mut body {
            o.set("n", 3u64);
        }
        let r = c.post_json("/api/ask/x", &body).unwrap();
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        let batch = r.json_body().unwrap();
        let trials = batch.get("trials").as_arr().unwrap();
        assert_eq!(trials.len(), 3);
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.get("trial_number").as_u64(), Some(i as u64));
            assert!(t.get("params").get("x").as_f64().is_some());
        }
        // Each suggested trial is individually tellable.
        for t in trials {
            let mut tell = Value::obj();
            tell.set("trial_id", t.get("trial_id").as_u64().unwrap()).set("value", 0.1);
            assert_eq!(c.post_json("/api/tell/x", &Value::Obj(tell)).unwrap().status, 200);
        }
        // Bare asks (no "n") keep the legacy single-object shape.
        let single = c.post_json("/api/ask/x", &ask_body()).unwrap().json_body().unwrap();
        assert!(single.get("trials").is_null());
        assert_eq!(single.get("trial_number").as_u64(), Some(3));
        // Invalid n: zero, too large, or non-integer are 422s.
        for bad in [Value::Num(0.0), Value::Num(1e6), Value::Num(1.5), Value::Str("x".into())] {
            let mut body = ask_body();
            if let Value::Obj(o) = &mut body {
                o.set("n", bad);
            }
            assert_eq!(c.post_json("/api/ask/x", &body).unwrap().status, 422);
        }
        s.stop();
    }
}

//! The HOPAAS coordination service — the paper's contribution.
//!
//! A central server orchestrates hyperparameter-optimization *studies*
//! across any number of heterogeneous client nodes through three POST
//! APIs (`ask`, `tell`, `should_prune`) plus a `version` probe (paper
//! Table 1). Studies are defined *by the clients*: the `ask` body carries
//! the full study definition (search space, direction, sampler, pruner),
//! and the server attaches the new trial to an existing study with the
//! same canonical definition or creates one — this is what lets nodes
//! from different sites join a campaign dynamically with no registration
//! step.
//!
//! Module map:
//! * [`space`] — search-space model (uniform / log-uniform / int /
//!   categorical) and parameter values;
//! * [`study`]/[`trial`] — state machines and the study registry;
//! * [`samplers`] — TPE (Optuna-default reproduction), GP-EI, CMA-ES,
//!   random, grid, Sobol;
//! * [`pruners`] — median, percentile, successive-halving (ASHA),
//!   hyperband, threshold, patient;
//! * [`auth`] — HMAC-signed API tokens with expiry + revocation;
//! * [`registry`] — the study directory and trial→shard router of the
//!   sharded engine (who lives where);
//! * [`replica`] — follower-side replication: transports over the
//!   primary's WAL stream, snapshot bootstrap, and the applier that
//!   keeps a read-only replica live until promotion;
//! * [`engine`] — the sharded, lock-disciplined core that the HTTP
//!   layer calls: N independent shards over a group-commit WAL (see
//!   `ARCHITECTURE.md` for the layer diagram and durability contract);
//! * [`service`] — HTTP handlers (Table 1 APIs + web/data APIs + the
//!   embedded dashboard);
//! * [`metrics`] — counters/histograms and the Prometheus endpoint,
//!   including per-shard and commit-batch series;
//! * [`views`] — epoch-stamped materialized read views (paginated
//!   dashboard pages, per-study event feeds) published by writers so
//!   readers never take shard locks.

pub mod auth;
pub mod engine;
pub mod metrics;
pub mod mo;
pub mod pruners;
pub mod registry;
pub mod replica;
pub mod samplers;
pub mod service;
pub mod space;
pub mod study;
pub mod trial;
pub mod views;

pub use engine::{Engine, EngineConfig};
pub use service::HopaasServer;

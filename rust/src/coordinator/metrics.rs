//! Service metrics: counters, gauges and latency histograms with a
//! Prometheus text-format endpoint (`/metrics`).
//!
//! The paper's web interface polls "specialized APIs at regular
//! intervals" for monitoring; operationally the same information must be
//! scrapeable, so the registry renders the standard exposition format.

use crate::rng;
use crate::sync::MutexExt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotone counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Gauge (set to arbitrary values).
#[derive(Default)]
pub struct Gauge {
    /// Stored as f64 bits.
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, x: f64) {
        self.v.store(x.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.v.load(Ordering::Relaxed))
    }
}

/// Latency histogram with fixed log-spaced bucket bounds (seconds).
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observations in microseconds (atomic integer to avoid a
    /// mutex on the hot path).
    sum_us: AtomicU64,
    /// Bounded reservoir of raw samples for quantiles in benches/tests.
    samples: Mutex<Reservoir>,
}

/// Uniform sample reservoir (Vitter's Algorithm R, with the crate's
/// deterministic mixer as the randomness source). Below the cap the
/// quantiles are exact; past it every observation still has a `cap/seen`
/// chance of being represented, so the quantiles keep tracking the live
/// distribution while memory stays fixed — a long-running server no
/// longer grows (or freezes, as the old push-until-full vector did)
/// its per-histogram sample set.
struct Reservoir {
    samples: Vec<f64>,
    /// Observations offered since the last [`Histogram::reset_samples`].
    seen: u64,
}

/// Raw samples retained per histogram (≈32 KiB of f64s).
const RESERVOIR_CAP: usize = 4096;

/// Escape a label value for the Prometheus exposition format
/// (backslash, double quote and newline must be backslash-escaped).
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Default API-latency bucket bounds: 50 µs … 10 s, log-spaced.
pub fn default_latency_bounds() -> Vec<f64> {
    let mut b = Vec::new();
    let mut x = 50e-6;
    while x < 10.0 {
        b.push(x);
        x *= 2.0;
    }
    b
}

/// Bucket bounds for `hopaas_ask_batch_size` (a count histogram, not a
/// latency one): powers of two up to the engine's batch cap.
pub fn ask_batch_bounds() -> Vec<f64> {
    vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
}

impl Histogram {
    pub fn new(bounds: Vec<f64>) -> Histogram {
        let n = bounds.len();
        Histogram {
            bounds,
            buckets: (0..=n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            samples: Mutex::new(Reservoir { samples: Vec::new(), seen: 0 }),
        }
    }

    /// Record an observation in seconds.
    pub fn observe(&self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us
            .fetch_add((x * 1e6).max(0.0) as u64, Ordering::Relaxed);
        let mut r = self.samples.lock_safe();
        r.seen += 1;
        if r.samples.len() < RESERVOIR_CAP {
            r.samples.push(x);
        } else {
            // Replace a uniformly chosen slot with probability cap/seen.
            let j = rng::mix(0x7265_7365_7276_6f69, r.seen) % r.seen;
            if (j as usize) < RESERVOIR_CAP {
                r.samples[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() / c as f64
        }
    }

    /// Quantile over the retained reservoir (q in [0,1]) — exact while
    /// under [`RESERVOIR_CAP`] observations, a uniform estimate past it.
    pub fn quantile(&self, q: f64) -> f64 {
        let mut s = self.samples.lock_safe().samples.clone();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(f64::total_cmp);
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }

    /// Clear retained samples (benches reuse histograms between phases).
    pub fn reset_samples(&self) {
        let mut r = self.samples.lock_safe();
        r.samples.clear();
        r.seen = 0;
    }
}

/// Per-shard series of the sharded engine (rendered with a
/// `shard="i"` label).
#[derive(Default)]
pub struct ShardMetrics {
    /// Mutations (ask/tell/should_prune/fail/reap) applied on the shard.
    pub ops: Counter,
    /// Studies owned by the shard.
    pub studies: Gauge,
    /// Live `last_seen` entries — running trials tracked for reaping.
    /// Must return to ~0 when campaigns finish (leak regression).
    pub tracked_running: Gauge,
}

/// All service metrics, named after the API surface.
pub struct Metrics {
    pub ask_total: Counter,
    pub tell_total: Counter,
    pub should_prune_total: Counter,
    pub prune_decisions: Counter,
    pub auth_failures: Counter,
    pub http_errors: Counter,
    pub studies_created: Counter,
    pub trials_created: Counter,
    pub trials_completed: Counter,
    pub trials_pruned: Counter,
    pub trials_failed: Counter,
    /// Failed auto-compaction attempts (snapshot write errors).
    pub compact_failures: Counter,
    /// Fleet counters: registrations, lost workers, lease-expiry
    /// requeues, requeued-trial re-assignments, quota denials (429s),
    /// affinity deferrals (requeued handouts held back for a healthier
    /// site).
    pub fleet_workers_registered: Counter,
    pub fleet_workers_lost: Counter,
    pub fleet_trials_requeued: Counter,
    pub fleet_trials_reassigned: Counter,
    pub fleet_quota_denials: Counter,
    pub fleet_affinity_deferrals: Counter,
    /// Sampler fit cache: asks served from a cached fit vs refits.
    pub sampler_cache_hits: Counter,
    pub sampler_cache_misses: Counter,
    /// Per-tenant 429 attribution (labeled counter; tenants are dynamic
    /// strings from token claims, so the series set grows with use).
    pub tenant_denials: Mutex<std::collections::BTreeMap<String, u64>>,
    pub wal_records: Gauge,
    /// Group-commit batches flushed (== fsync count under load).
    pub wal_commit_batches: Gauge,
    /// Records committed through the group-commit writer.
    pub wal_commit_records: Gauge,
    /// Size of the most recent commit batch.
    pub wal_commit_last_batch: Gauge,
    /// Largest commit batch observed.
    pub wal_commit_max_batch: Gauge,
    /// Records replayed at the last recovery (startup).
    pub wal_recovered_records: Gauge,
    /// Torn-tail truncation incidents observed at the last recovery.
    pub wal_truncated_records: Gauge,
    /// Bytes discarded with those torn tails.
    pub wal_truncated_bytes: Gauge,
    /// Records skipped at recovery because a snapshot segment covers
    /// them (crash inside a compaction window).
    pub wal_filtered_records: Gauge,
    /// Live group-commit batch limit (adaptive batching).
    pub wal_commit_batch_limit: Gauge,
    /// Segment cuts skipped by clean-shard reuse (lifetime total).
    pub compact_segments_reused: Gauge,
    /// Replication lag in records (follower: primary next_seq − local
    /// cursor; 0 on a primary or a caught-up follower).
    pub repl_lag_seq: Gauge,
    /// Seconds the follower has continuously been behind the primary
    /// (0 when caught up).
    pub repl_lag_seconds: Gauge,
    /// Side threads the last compaction used to cut segments.
    pub compact_pool_threads: Gauge,
    /// Fleet gauges, refreshed at scrape time.
    pub fleet_workers_alive: Gauge,
    pub fleet_leases: Gauge,
    pub fleet_requeue_depth: Gauge,
    /// Per-site active lease counts (labeled series; sites are dynamic
    /// strings, so a scrape-time snapshot replaces the whole vector).
    pub site_leases: Mutex<Vec<(String, f64)>>,
    /// Per-tenant active lease counts (`hopaas_tenant_leases`), same
    /// scrape-time snapshot discipline as `site_leases`.
    pub tenant_leases: Mutex<Vec<(String, f64)>>,
    /// Read-path gauges: worst view lag across studies (tell-epochs
    /// between a study's runtime epoch and its published view — 0 under
    /// synchronous publication; >0 would flag a missed hook) and the
    /// number of long-poll readers currently parked on `/events`.
    pub view_staleness_epochs: Gauge,
    pub events_waiters: Gauge,
    /// Seconds since the engine started — refreshed at scrape time so
    /// dashboards can correlate deploys/restarts with latency shifts.
    pub uptime_seconds: Gauge,
    pub ask_latency: Histogram,
    pub tell_latency: Histogram,
    pub should_prune_latency: Histogram,
    /// Wall time of materialized-view publications (the writer-side cost
    /// of keeping reader snapshots fresh).
    pub view_refresh_seconds: Histogram,
    /// Wall time of individual segment cuts (write → fsync → rename),
    /// wherever they run — the compaction pool's unit of work.
    pub compact_segment_seconds: Histogram,
    /// Wall time of sampler refits (`Sampler::fit`) on the ask path.
    pub sampler_fit_seconds: Histogram,
    /// Requested batch size per ask request (`n`, 1 for legacy asks).
    pub ask_batch_size: Histogram,
    /// One entry per engine shard; empty outside the engine (e.g. bare
    /// `Metrics::default()` in unit tests).
    pub shards: Vec<ShardMetrics>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_shards(0)
    }
}

impl Metrics {
    /// Registry sized for an engine with `n` shards.
    pub fn with_shards(n: usize) -> Metrics {
        Metrics {
            ask_total: Counter::default(),
            tell_total: Counter::default(),
            should_prune_total: Counter::default(),
            prune_decisions: Counter::default(),
            auth_failures: Counter::default(),
            http_errors: Counter::default(),
            studies_created: Counter::default(),
            trials_created: Counter::default(),
            trials_completed: Counter::default(),
            trials_pruned: Counter::default(),
            trials_failed: Counter::default(),
            compact_failures: Counter::default(),
            fleet_workers_registered: Counter::default(),
            fleet_workers_lost: Counter::default(),
            fleet_trials_requeued: Counter::default(),
            fleet_trials_reassigned: Counter::default(),
            fleet_quota_denials: Counter::default(),
            fleet_affinity_deferrals: Counter::default(),
            sampler_cache_hits: Counter::default(),
            sampler_cache_misses: Counter::default(),
            tenant_denials: Mutex::new(std::collections::BTreeMap::new()),
            wal_records: Gauge::default(),
            wal_commit_batches: Gauge::default(),
            wal_commit_records: Gauge::default(),
            wal_commit_last_batch: Gauge::default(),
            wal_commit_max_batch: Gauge::default(),
            wal_recovered_records: Gauge::default(),
            wal_truncated_records: Gauge::default(),
            wal_truncated_bytes: Gauge::default(),
            wal_filtered_records: Gauge::default(),
            wal_commit_batch_limit: Gauge::default(),
            compact_segments_reused: Gauge::default(),
            repl_lag_seq: Gauge::default(),
            repl_lag_seconds: Gauge::default(),
            compact_pool_threads: Gauge::default(),
            fleet_workers_alive: Gauge::default(),
            fleet_leases: Gauge::default(),
            fleet_requeue_depth: Gauge::default(),
            site_leases: Mutex::new(Vec::new()),
            tenant_leases: Mutex::new(Vec::new()),
            view_staleness_epochs: Gauge::default(),
            events_waiters: Gauge::default(),
            uptime_seconds: Gauge::default(),
            ask_latency: Histogram::new(default_latency_bounds()),
            tell_latency: Histogram::new(default_latency_bounds()),
            should_prune_latency: Histogram::new(default_latency_bounds()),
            view_refresh_seconds: Histogram::new(default_latency_bounds()),
            compact_segment_seconds: Histogram::new(default_latency_bounds()),
            sampler_fit_seconds: Histogram::new(default_latency_bounds()),
            ask_batch_size: Histogram::new(ask_batch_bounds()),
            shards: (0..n).map(|_| ShardMetrics::default()).collect(),
        }
    }

    /// Count a tenant-attributed quota denial (labeled 429 series).
    /// Tenant names are client-influenced (token claims, or the body
    /// field on `--no-auth` servers), so the series set is bounded:
    /// past the cap, new tenants aggregate into an `_other` bucket
    /// instead of growing memory and scrape cardinality forever.
    pub fn inc_tenant_denial(&self, tenant: &str) {
        const MAX_TENANT_SERIES: usize = 1024;
        let mut m = self.tenant_denials.lock_safe();
        if m.len() >= MAX_TENANT_SERIES && !m.contains_key(tenant) {
            *m.entry("_other".to_string()).or_insert(0) += 1;
            return;
        }
        *m.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Render Prometheus text exposition format. Every family emits
    /// `# HELP` then `# TYPE` exactly once, before any of its samples —
    /// the whole-scrape conformance contract the lint test enforces.
    pub fn render(&self) -> String {
        fn family(out: &mut String, name: &str, kind: &str, help: &str) {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        }
        let mut out = String::with_capacity(8192);
        // Build identity: a constant-1 gauge whose labels carry the
        // version and git hash, so dashboards can correlate deploys
        // with latency shifts.
        family(&mut out, "hopaas_build_info", "gauge", "Build identity (constant 1).");
        out.push_str(&format!(
            "hopaas_build_info{{version=\"{}\",git_hash=\"{}\"}} 1\n",
            escape_label(crate::VERSION),
            escape_label(crate::GIT_HASH.unwrap_or("unknown")),
        ));
        let counters: [(&str, &str, &Counter); 20] = [
            ("hopaas_ask_total", "Ask requests served.", &self.ask_total),
            ("hopaas_tell_total", "Tell requests served.", &self.tell_total),
            (
                "hopaas_should_prune_total",
                "Prune queries served.",
                &self.should_prune_total,
            ),
            (
                "hopaas_prune_decisions_total",
                "Prune queries answered true.",
                &self.prune_decisions,
            ),
            ("hopaas_auth_failures_total", "Rejected credentials.", &self.auth_failures),
            ("hopaas_http_errors_total", "Non-2xx API responses.", &self.http_errors),
            ("hopaas_studies_created_total", "Studies created.", &self.studies_created),
            ("hopaas_trials_created_total", "Trials created.", &self.trials_created),
            (
                "hopaas_trials_completed_total",
                "Trials completed via tell.",
                &self.trials_completed,
            ),
            ("hopaas_trials_pruned_total", "Trials pruned.", &self.trials_pruned),
            ("hopaas_trials_failed_total", "Trials failed.", &self.trials_failed),
            (
                "hopaas_compact_failures_total",
                "Failed auto-compaction attempts.",
                &self.compact_failures,
            ),
            (
                "hopaas_fleet_workers_registered_total",
                "Worker registrations.",
                &self.fleet_workers_registered,
            ),
            (
                "hopaas_fleet_workers_lost_total",
                "Workers lost to lease expiry.",
                &self.fleet_workers_lost,
            ),
            (
                "hopaas_fleet_trials_requeued_total",
                "Trials requeued after preemption.",
                &self.fleet_trials_requeued,
            ),
            (
                "hopaas_fleet_trials_reassigned_total",
                "Requeued trials re-assigned.",
                &self.fleet_trials_reassigned,
            ),
            (
                "hopaas_fleet_quota_denials_total",
                "Asks denied by quota (429).",
                &self.fleet_quota_denials,
            ),
            (
                "hopaas_fleet_affinity_deferrals_total",
                "Requeued handouts deferred for a healthier site.",
                &self.fleet_affinity_deferrals,
            ),
            (
                "hopaas_sampler_cache_hits_total",
                "Asks served from a cached sampler fit.",
                &self.sampler_cache_hits,
            ),
            (
                "hopaas_sampler_cache_misses_total",
                "Asks that refit the sampler.",
                &self.sampler_cache_misses,
            ),
        ];
        for (name, help, c) in counters {
            family(&mut out, name, "counter", help);
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        {
            let tenants = self.tenant_denials.lock_safe();
            if !tenants.is_empty() {
                family(
                    &mut out,
                    "hopaas_tenant_quota_denials_total",
                    "counter",
                    "Quota denials (429) by tenant.",
                );
                for (tenant, n) in tenants.iter() {
                    let tenant = escape_label(tenant);
                    out.push_str(&format!(
                        "hopaas_tenant_quota_denials_total{{tenant=\"{tenant}\"}} {n}\n"
                    ));
                }
            }
        }
        for (name, help, g) in [
            ("hopaas_wal_records", "Records in the active WAL epoch.", &self.wal_records),
            (
                "hopaas_wal_commit_batches",
                "Group-commit batches flushed (fsync count).",
                &self.wal_commit_batches,
            ),
            (
                "hopaas_wal_commit_records",
                "Records committed through the group-commit writer.",
                &self.wal_commit_records,
            ),
            (
                "hopaas_wal_commit_last_batch",
                "Size of the most recent commit batch.",
                &self.wal_commit_last_batch,
            ),
            (
                "hopaas_wal_commit_max_batch",
                "Largest commit batch observed.",
                &self.wal_commit_max_batch,
            ),
            (
                "hopaas_wal_recovered_records",
                "Records replayed at the last recovery.",
                &self.wal_recovered_records,
            ),
            (
                "hopaas_wal_truncated_records",
                "Torn-tail truncations at the last recovery.",
                &self.wal_truncated_records,
            ),
            (
                "hopaas_wal_truncated_bytes",
                "Bytes discarded with torn tails.",
                &self.wal_truncated_bytes,
            ),
            (
                "hopaas_wal_filtered_records",
                "Records skipped at recovery (covered by a segment).",
                &self.wal_filtered_records,
            ),
            (
                "hopaas_wal_commit_batch_limit",
                "Live adaptive group-commit batch limit.",
                &self.wal_commit_batch_limit,
            ),
            (
                "hopaas_repl_lag_seq",
                "Replication lag in records (0 on primaries).",
                &self.repl_lag_seq,
            ),
            (
                "hopaas_repl_lag_seconds",
                "Seconds continuously behind the primary (0 when caught up).",
                &self.repl_lag_seconds,
            ),
            (
                "hopaas_compact_segments_reused",
                "Segment cuts skipped by clean-shard reuse.",
                &self.compact_segments_reused,
            ),
            (
                "hopaas_compact_pool_threads",
                "Side threads used by the last compaction.",
                &self.compact_pool_threads,
            ),
            (
                "hopaas_fleet_workers_alive",
                "Workers currently alive.",
                &self.fleet_workers_alive,
            ),
            ("hopaas_fleet_leases", "Active trial leases.", &self.fleet_leases),
            (
                "hopaas_fleet_requeue_depth",
                "Preempted trials awaiting re-assignment.",
                &self.fleet_requeue_depth,
            ),
            (
                "hopaas_view_staleness_epochs",
                "Worst runtime-vs-published view epoch lag.",
                &self.view_staleness_epochs,
            ),
            (
                "hopaas_events_waiters",
                "Long-poll readers parked on /events.",
                &self.events_waiters,
            ),
            (
                "hopaas_uptime_seconds",
                "Seconds since the engine started.",
                &self.uptime_seconds,
            ),
        ] {
            family(&mut out, name, "gauge", help);
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        {
            let sites = self.site_leases.lock_safe();
            if !sites.is_empty() {
                family(&mut out, "hopaas_site_leases", "gauge", "Active leases by site.");
                for (site, n) in sites.iter() {
                    // Site names are client-supplied: escape them per the
                    // exposition format or one register with a quote in
                    // it would corrupt the whole scrape.
                    let site = escape_label(site);
                    out.push_str(&format!("hopaas_site_leases{{site=\"{site}\"}} {n}\n"));
                }
            }
        }
        {
            let tenants = self.tenant_leases.lock_safe();
            if !tenants.is_empty() {
                family(&mut out, "hopaas_tenant_leases", "gauge", "Active leases by tenant.");
                for (tenant, n) in tenants.iter() {
                    // Tenant names come from token claims: escape them
                    // like site labels.
                    let tenant = escape_label(tenant);
                    out.push_str(&format!("hopaas_tenant_leases{{tenant=\"{tenant}\"}} {n}\n"));
                }
            }
        }
        if !self.shards.is_empty() {
            family(&mut out, "hopaas_engine_shards", "gauge", "Engine shard count.");
            out.push_str(&format!("hopaas_engine_shards {}\n", self.shards.len()));
            family(
                &mut out,
                "hopaas_shard_ops_total",
                "counter",
                "Mutations applied, by shard.",
            );
            for (i, s) in self.shards.iter().enumerate() {
                out.push_str(&format!(
                    "hopaas_shard_ops_total{{shard=\"{i}\"}} {}\n",
                    s.ops.get()
                ));
            }
            family(&mut out, "hopaas_shard_studies", "gauge", "Studies owned, by shard.");
            for (i, s) in self.shards.iter().enumerate() {
                out.push_str(&format!(
                    "hopaas_shard_studies{{shard=\"{i}\"}} {}\n",
                    s.studies.get()
                ));
            }
            family(
                &mut out,
                "hopaas_shard_tracked_running",
                "gauge",
                "Running trials tracked for reaping, by shard.",
            );
            for (i, s) in self.shards.iter().enumerate() {
                out.push_str(&format!(
                    "hopaas_shard_tracked_running{{shard=\"{i}\"}} {}\n",
                    s.tracked_running.get()
                ));
            }
        }
        for (name, help, h) in [
            ("hopaas_ask_latency_seconds", "Ask request latency.", &self.ask_latency),
            ("hopaas_tell_latency_seconds", "Tell request latency.", &self.tell_latency),
            (
                "hopaas_should_prune_latency_seconds",
                "Prune-query latency.",
                &self.should_prune_latency,
            ),
            (
                "hopaas_compact_segment_seconds",
                "Wall time of individual segment cuts.",
                &self.compact_segment_seconds,
            ),
            (
                "hopaas_sampler_fit_seconds",
                "Wall time of sampler refits on the ask path.",
                &self.sampler_fit_seconds,
            ),
            (
                "hopaas_view_refresh_seconds",
                "Wall time of materialized-view publications.",
                &self.view_refresh_seconds,
            ),
            ("hopaas_ask_batch_size", "Requested batch size per ask.", &self.ask_batch_size),
        ] {
            family(&mut out, name, "histogram", help);
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.buckets[i].load(Ordering::Relaxed);
                out.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{name}_sum {}\n", h.sum()));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new(default_latency_bounds());
        for i in 1..=100 {
            h.observe(i as f64 / 1000.0); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.quantile(0.5) - 0.05).abs() < 0.005);
        assert!((h.quantile(0.99) - 0.1).abs() < 0.005);
        assert!((h.mean() - 0.0505).abs() < 0.001);
    }

    #[test]
    fn render_contains_series() {
        let m = Metrics::default();
        m.ask_total.inc();
        m.ask_latency.observe(0.001);
        let text = m.render();
        assert!(text.contains("hopaas_ask_total 1"));
        assert!(text.contains("hopaas_ask_latency_seconds_count 1"));
        assert!(text.contains("le=\"+Inf\"} 1"));
        // Buckets are cumulative.
        let inf_line = text.lines().find(|l| l.contains("ask") && l.contains("+Inf")).unwrap();
        assert!(inf_line.ends_with('1'));
    }

    #[test]
    fn shard_series_rendered_with_labels() {
        let m = Metrics::with_shards(2);
        m.shards[0].ops.add(3);
        m.shards[1].studies.set(4.0);
        m.shards[1].tracked_running.set(2.0);
        m.wal_commit_batches.set(5.0);
        m.wal_recovered_records.set(123.0);
        m.wal_truncated_records.set(1.0);
        let text = m.render();
        assert!(text.contains("hopaas_wal_recovered_records 123"));
        assert!(text.contains("hopaas_wal_truncated_records 1"));
        assert!(text.contains("hopaas_engine_shards 2"));
        assert!(text.contains("hopaas_shard_ops_total{shard=\"0\"} 3"));
        assert!(text.contains("hopaas_shard_studies{shard=\"1\"} 4"));
        assert!(text.contains("hopaas_shard_tracked_running{shard=\"1\"} 2"));
        assert!(text.contains("hopaas_wal_commit_batches 5"));
        // No shard series when the registry has no shards.
        assert!(!Metrics::default().render().contains("hopaas_shard_ops_total"));
    }

    #[test]
    fn fleet_series_rendered() {
        let m = Metrics::default();
        m.fleet_workers_registered.inc();
        m.fleet_quota_denials.add(2);
        m.fleet_leases.set(3.0);
        m.wal_commit_batch_limit.set(64.0);
        *m.site_leases.lock().unwrap() =
            vec![("infn-cloud".into(), 2.0), ("a\"b\nc\\d".into(), 1.0)];
        let text = m.render();
        assert!(text.contains("hopaas_fleet_workers_registered_total 1"));
        assert!(text.contains("hopaas_fleet_quota_denials_total 2"));
        assert!(text.contains("hopaas_fleet_leases 3"));
        assert!(text.contains("hopaas_wal_commit_batch_limit 64"));
        assert!(text.contains("hopaas_site_leases{site=\"infn-cloud\"} 2"));
        // Hostile site names are escaped, not emitted raw.
        assert!(text.contains("hopaas_site_leases{site=\"a\\\"b\\nc\\\\d\"} 1"));
        // No site series while the fleet is empty.
        assert!(!Metrics::default().render().contains("hopaas_site_leases"));
    }

    #[test]
    fn tenant_series_rendered() {
        let m = Metrics::default();
        m.inc_tenant_denial("alice");
        m.inc_tenant_denial("alice");
        m.inc_tenant_denial("b\"ob");
        m.fleet_affinity_deferrals.inc();
        *m.tenant_leases.lock().unwrap() = vec![("alice".into(), 3.0)];
        let text = m.render();
        assert!(text.contains("hopaas_tenant_quota_denials_total{tenant=\"alice\"} 2"));
        assert!(text.contains("hopaas_tenant_quota_denials_total{tenant=\"b\\\"ob\"} 1"));
        assert!(text.contains("hopaas_tenant_leases{tenant=\"alice\"} 3"));
        assert!(text.contains("hopaas_fleet_affinity_deferrals_total 1"));
        // No tenant series while nothing tenant-scoped happened.
        let empty = Metrics::default().render();
        assert!(!empty.contains("hopaas_tenant_quota_denials_total{"));
        assert!(!empty.contains("hopaas_tenant_leases{"));
    }

    #[test]
    fn tenant_denial_series_bounded() {
        let m = Metrics::default();
        // Fill the series cap, then overflow: hostile/unique tenant
        // names past the cap land in the `_other` bucket.
        for i in 0..1024 {
            m.inc_tenant_denial(&format!("t{i}"));
        }
        m.inc_tenant_denial("fresh-1");
        m.inc_tenant_denial("fresh-2");
        m.inc_tenant_denial("t0"); // existing keys still count normally
        {
            let map = m.tenant_denials.lock().unwrap();
            assert_eq!(map.get("_other"), Some(&2));
            assert_eq!(map.get("t0"), Some(&2));
            assert!(map.get("fresh-1").is_none());
            assert!(map.len() <= 1025, "bounded at cap + overflow bucket");
        }
        assert!(m.render().contains("hopaas_tenant_quota_denials_total{tenant=\"_other\"} 2"));
    }

    #[test]
    fn sampler_series_rendered() {
        let m = Metrics::default();
        m.sampler_cache_hits.add(7);
        m.sampler_cache_misses.inc();
        m.sampler_fit_seconds.observe(0.002);
        m.ask_batch_size.observe(8.0);
        let text = m.render();
        assert!(text.contains("hopaas_sampler_cache_hits_total 7"));
        assert!(text.contains("hopaas_sampler_cache_misses_total 1"));
        assert!(text.contains("hopaas_sampler_fit_seconds_count 1"));
        assert!(text.contains("hopaas_ask_batch_size_count 1"));
        // Batch-size buckets are counts, not latencies: an 8-trial ask
        // lands in the le="8" bucket.
        assert!(text.contains("hopaas_ask_batch_size_bucket{le=\"8\"} 1"));
        assert!((m.ask_batch_size.mean() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_reservoir_memory_stable_under_one_million_observes() {
        let h = Histogram::new(default_latency_bounds());
        for i in 0..1_000_000u64 {
            // 0..100 ms, uniform.
            h.observe((i % 1000) as f64 / 10_000.0);
        }
        assert_eq!(h.count(), 1_000_000);
        {
            let r = h.samples.lock().unwrap();
            assert_eq!(r.seen, 1_000_000);
            assert_eq!(r.samples.len(), RESERVOIR_CAP, "retention bounded at the cap");
            assert!(
                r.samples.capacity() <= 2 * RESERVOIR_CAP,
                "no unbounded growth ({} slots allocated)",
                r.samples.capacity()
            );
        }
        // Past the cap the quantiles still track the live distribution
        // (the old push-until-full vector froze on the first 100k).
        let q50 = h.quantile(0.5);
        assert!((0.03..=0.07).contains(&q50), "median ≈ 50ms, got {q50}");
        // Bench reset behavior: a reset reservoir starts exact again.
        h.reset_samples();
        assert_eq!(h.quantile(0.5), 0.0);
        h.observe(0.25);
        assert_eq!(h.quantile(0.5), 0.25);
        assert_eq!(h.samples.lock().unwrap().seen, 1);
    }

    #[test]
    fn build_info_and_uptime_rendered() {
        let m = Metrics::default();
        m.uptime_seconds.set(12.0);
        let text = m.render();
        assert!(text.contains("# TYPE hopaas_build_info gauge"));
        assert!(text.contains(&format!("version=\"{}\"", crate::VERSION)));
        assert!(text.contains("git_hash="));
        assert!(text.contains("} 1\n"), "build info value is the constant 1");
        assert!(text.contains("hopaas_uptime_seconds 12"));
    }

    #[test]
    fn every_family_has_help_before_type() {
        let m = Metrics::with_shards(2);
        m.inc_tenant_denial("alice");
        *m.site_leases.lock().unwrap() = vec![("cnaf".into(), 1.0)];
        *m.tenant_leases.lock().unwrap() = vec![("alice".into(), 1.0)];
        let text = m.render();
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split_whitespace().next().unwrap();
                assert!(
                    i > 0 && lines[i - 1].starts_with(&format!("# HELP {name} ")),
                    "HELP must immediately precede TYPE for {name}"
                );
            }
        }
    }

    #[test]
    fn histogram_bucket_monotone() {
        let h = Histogram::new(vec![0.001, 0.01, 0.1]);
        for x in [0.0005, 0.005, 0.05, 0.5] {
            h.observe(x);
        }
        let counts: Vec<u64> = h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert_eq!(counts, vec![1, 1, 1, 1]);
        assert_eq!(h.count(), 4);
    }
}

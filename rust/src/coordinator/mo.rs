//! Multi-objective optimization support — the paper's §5 future work
//! ("introduce support to multi-objective optimizations"), implemented
//! as a first-class feature.
//!
//! A multi-objective study declares `"direction": ["minimize",
//! "maximize", ...]`; `tell` carries `"values": [v0, v1, ...]`. This
//! module provides the machinery: Pareto dominance, fast non-dominated
//! sorting (Deb et al. 2002), crowding distance, Pareto-front
//! extraction, and hypervolume (exact 2-D sweep, Monte-Carlo for ≥3
//! objectives) — the standard quality indicator the MO benches report.
//!
//! All routines operate on minimization-oriented vectors; callers flip
//! maximize objectives (see [`orient`]).

use super::space::Direction;

/// Orient a raw objective vector so every component is minimized.
pub fn orient(values: &[f64], directions: &[Direction]) -> Vec<f64> {
    values
        .iter()
        .zip(directions)
        .map(|(&v, d)| match d {
            Direction::Minimize => v,
            Direction::Maximize => -v,
        })
        .collect()
}

/// `a` Pareto-dominates `b` (minimization): no worse everywhere,
/// strictly better somewhere.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort: returns fronts as index lists, best first.
pub fn non_dominated_sort(points: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut domination_count = vec![0usize; n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if dominates(&points[i], &points[j]) {
                dominated_by[i].push(j);
            } else if dominates(&points[j], &points[i]) {
                domination_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| domination_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                domination_count[j] -= 1;
                if domination_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each point within one front (Deb et al. 2002).
/// Boundary points get `f64::INFINITY`.
pub fn crowding_distance(points: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let m = points.first().map_or(0, |p| p.len());
    let k = front.len();
    let mut dist = vec![0.0f64; k];
    if k <= 2 {
        return vec![f64::INFINITY; k];
    }
    for obj in 0..m {
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| points[front[a]][obj].total_cmp(&points[front[b]][obj]));
        let lo = points[front[order[0]]][obj];
        let hi = points[front[order[k - 1]]][obj];
        let span = (hi - lo).max(1e-300);
        dist[order[0]] = f64::INFINITY;
        dist[order[k - 1]] = f64::INFINITY;
        for w in 1..k - 1 {
            let prev = points[front[order[w - 1]]][obj];
            let next = points[front[order[w + 1]]][obj];
            dist[order[w]] += (next - prev) / span;
        }
    }
    dist
}

/// Indices of the Pareto-optimal points (first front).
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    if points.is_empty() {
        return Vec::new();
    }
    non_dominated_sort(points).remove(0)
}

/// Hypervolume dominated by `points` against `reference` (minimization;
/// every point must weakly dominate the reference to contribute).
/// Exact sweep for 2-D; Monte-Carlo with `mc_samples` for ≥3-D.
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64], mc_samples: usize) -> f64 {
    let pts: Vec<&Vec<f64>> = points
        .iter()
        .filter(|p| p.iter().zip(reference).all(|(x, r)| x <= r))
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    match reference.len() {
        1 => {
            let best = pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
            (reference[0] - best).max(0.0)
        }
        2 => {
            // Sort by first objective; sweep.
            let front = pareto_front(&pts.iter().map(|p| (*p).clone()).collect::<Vec<_>>());
            let mut fp: Vec<&Vec<f64>> = front.iter().map(|&i| pts[i]).collect();
            fp.sort_by(|a, b| a[0].total_cmp(&b[0]));
            let mut hv = 0.0;
            let mut prev_y = reference[1];
            for p in fp {
                hv += (reference[0] - p[0]) * (prev_y - p[1]);
                prev_y = p[1];
            }
            hv
        }
        m => {
            // Monte-Carlo over the box [ideal, reference].
            let mut ideal = vec![f64::INFINITY; m];
            for p in &pts {
                for (i, &x) in p.iter().enumerate() {
                    ideal[i] = ideal[i].min(x);
                }
            }
            let volume: f64 = ideal
                .iter()
                .zip(reference)
                .map(|(&a, &r)| (r - a).max(0.0))
                .product();
            if volume == 0.0 {
                return 0.0;
            }
            let mut rng = crate::rng::Rng::new(0xFACE);
            let mut hits = 0usize;
            let samples = mc_samples.max(1000);
            for _ in 0..samples {
                let x: Vec<f64> = ideal
                    .iter()
                    .zip(reference)
                    .map(|(&a, &r)| rng.uniform(a, r))
                    .collect();
                if pts.iter().any(|p| p.iter().zip(&x).all(|(a, b)| a <= b)) {
                    hits += 1;
                }
            }
            volume * hits as f64 / samples as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]), "incomparable");
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal not strict");
    }

    #[test]
    fn nds_fronts_ordered() {
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![2.0, 2.0], // front 0
            vec![4.0, 1.0], // front 0
            vec![3.0, 3.0], // front 1 (dominated by [2,2])
            vec![5.0, 5.0], // front 2
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 3);
        let mut f0 = fronts[0].clone();
        f0.sort();
        assert_eq!(f0, vec![0, 1, 2]);
        assert_eq!(fronts[1], vec![3]);
        assert_eq!(fronts[2], vec![4]);
    }

    #[test]
    fn prop_first_front_is_mutually_nondominated() {
        prop::check(100, |g| {
            let n = g.usize(1, 20);
            let pts: Vec<Vec<f64>> =
                (0..n).map(|_| vec![g.f64(0.0, 1.0), g.f64(0.0, 1.0)]).collect();
            let front = pareto_front(&pts);
            for &i in &front {
                for &j in &front {
                    if i != j && dominates(&pts[i], &pts[j]) {
                        return Err(format!("{i} dominates {j} within front"));
                    }
                }
                // And nothing outside dominates a front member.
                for (k, p) in pts.iter().enumerate() {
                    if !front.contains(&k) && dominates(p, &pts[i]) {
                        return Err(format!("outsider {k} dominates front member {i}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let pts = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        let d = crowding_distance(&pts, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn hypervolume_2d_exact() {
        // Single point (1,1) vs ref (2,2): hv = 1.
        assert!((hypervolume(&[vec![1.0, 1.0]], &[2.0, 2.0], 0) - 1.0).abs() < 1e-12);
        // Two points forming a staircase.
        let hv = hypervolume(&[vec![1.0, 2.0], vec![2.0, 1.0]], &[3.0, 3.0], 0);
        // (3-1)(3-2) + (3-2)(2-1) = 2 + 1 = 3.
        assert!((hv - 3.0).abs() < 1e-12, "hv={hv}");
        // Dominated point adds nothing.
        let hv2 = hypervolume(
            &[vec![1.0, 2.0], vec![2.0, 1.0], vec![2.5, 2.5]],
            &[3.0, 3.0],
            0,
        );
        assert!((hv2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_monotone_in_points() {
        prop::check(50, |g| {
            let pts: Vec<Vec<f64>> = (0..g.usize(1, 8))
                .map(|_| vec![g.f64(0.0, 1.0), g.f64(0.0, 1.0)])
                .collect();
            let hv1 = hypervolume(&pts, &[1.5, 1.5], 0);
            let mut more = pts.clone();
            more.push(vec![g.f64(0.0, 1.0), g.f64(0.0, 1.0)]);
            let hv2 = hypervolume(&more, &[1.5, 1.5], 0);
            prop::assert_holds(hv2 >= hv1 - 1e-12, format!("{hv2} < {hv1}"))
        });
    }

    #[test]
    fn hypervolume_3d_mc_close_to_exact_box() {
        // One point at origin vs ref (1,1,1): exact hv = 1.
        let hv = hypervolume(&[vec![0.0, 0.0, 0.0]], &[1.0, 1.0, 1.0], 20_000);
        assert!((hv - 1.0).abs() < 0.05, "hv={hv}");
    }

    #[test]
    fn orient_flips_maximize() {
        let v = orient(&[1.0, 2.0], &[Direction::Minimize, Direction::Maximize]);
        assert_eq!(v, vec![1.0, -2.0]);
    }
}

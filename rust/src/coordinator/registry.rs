//! Registry layer of the sharded engine: who lives where.
//!
//! The engine splits its state across N independent shards so that
//! mutations on different studies never contend (see `engine.rs`). Two
//! small read-mostly structures make that routable:
//!
//! * [`Directory`] — the study directory: an append-only list of
//!   `(study_id, shard, slot)` entries behind a `RwLock`, serving the
//!   cross-study read APIs (`/api/studies`, `/metrics`, dashboard
//!   series) without touching shard locks while held;
//! * [`TrialRouter`] — a lock-striped `trial_id → shard` map, written
//!   once per `ask` and read once per `tell`/`should_prune`/`fail`.
//!
//! Their places in the canonical lock order (declared once in
//! [`crate::analysis::HIERARCHY`], enforced by `hopaas-lint`) differ:
//! the directory sits *below* the shard locks — writers stage a
//! [`DirEntry`] and publish it only after the shard guard drops — while
//! router stripes sit *above* them, so a shard lock may be held while
//! taking a stripe lock, never the other way around.
//!
//! Study→shard placement is *stable*: `shard_of = fnv1a(study_key) %
//! n_shards`. The same FNV-1a hash seeds the deterministic sampler
//! streams, so placement, like suggestions, is a pure function of the
//! study definition — a recovered or second engine instance routes
//! identically.

use crate::sync::MutexExt;
use std::collections::HashMap;
use std::sync::Mutex;

/// FNV-1a 64-bit hash of a study key. This exact function (offset basis
/// `0xcbf29ce484222325`, prime `0x100000001b3`) has seeded the sampler
/// streams since the seed engine — suggestion determinism depends on it
/// staying byte-for-byte identical.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Stable placement of a study key into one of `n` buckets. Used for
/// both live shard routing and the parallel-replay partitioner, so a
/// study's records always replay on the thread that owns its state —
/// whatever shard count wrote them.
pub fn place(key: &str, n: usize) -> usize {
    (fnv1a(key) % n.max(1) as u64) as usize
}

/// One study's location: which shard owns it and at which slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DirEntry {
    pub id: u64,
    pub shard: usize,
    /// Index into the owning shard's `studies` vector. Slots are stable:
    /// studies are never removed.
    pub slot: usize,
}

/// Append-only study directory. Entries arrive in creation order, which
/// under concurrency may not be id order — readers that need id order
/// sort (ids are dense and small, studies number in the dozens).
#[derive(Default)]
pub struct Directory {
    entries: Vec<DirEntry>,
}

impl Directory {
    pub fn push(&mut self, entry: DirEntry) {
        self.entries.push(entry);
    }

    pub fn lookup(&self, id: u64) -> Option<DirEntry> {
        self.entries.iter().find(|e| e.id == id).copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries sorted by study id (creation order for readers).
    pub fn sorted(&self) -> Vec<DirEntry> {
        let mut v = self.entries.clone();
        v.sort_by_key(|e| e.id);
        v
    }
}

const STRIPES: usize = 16;

/// Lock-striped `trial_id → shard` routing table.
///
/// `tell`/`should_prune`/`fail` arrive with only a trial id; this maps
/// it to the owning shard without a global lock. Striping by
/// `trial_id % 16` keeps writers (one insert per `ask`) from contending
/// on a single mutex.
pub struct TrialRouter {
    stripes: Vec<Mutex<HashMap<u64, u32>>>,
}

impl Default for TrialRouter {
    fn default() -> Self {
        TrialRouter {
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }
}

impl TrialRouter {
    fn stripe(&self, trial_id: u64) -> &Mutex<HashMap<u64, u32>> {
        &self.stripes[(trial_id as usize) % STRIPES]
    }

    pub fn insert(&self, trial_id: u64, shard: usize) {
        self.stripe(trial_id)
            .lock_safe()
            .insert(trial_id, shard as u32);
    }

    pub fn get(&self, trial_id: u64) -> Option<usize> {
        self.stripe(trial_id)
            .lock_safe()
            .get(&trial_id)
            .map(|&s| s as usize)
    }

    /// Number of routed trials (tests/metrics).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock_safe().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_seed_engine_constants() {
        // Locked-in values: suggestion determinism and shard placement
        // both hash with this function. If these change, every stored
        // campaign's replay seeds change with them.
        assert_eq!(fnv1a(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a("hopaas"), {
            let mut h: u64 = 0xcbf29ce484222325;
            for b in b"hopaas" {
                h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
            }
            h
        });
    }

    #[test]
    fn place_is_stable_and_in_range() {
        for key in ["", "a", "hopaas", "study-42"] {
            assert_eq!(place(key, 8), (fnv1a(key) % 8) as usize);
            assert!(place(key, 3) < 3);
            assert_eq!(place(key, 1), 0);
            // Degenerate bucket count clamps instead of dividing by 0.
            assert_eq!(place(key, 0), 0);
        }
    }

    #[test]
    fn directory_lookup_and_order() {
        let mut d = Directory::default();
        d.push(DirEntry { id: 2, shard: 1, slot: 0 });
        d.push(DirEntry { id: 1, shard: 0, slot: 0 });
        d.push(DirEntry { id: 3, shard: 1, slot: 1 });
        assert_eq!(d.len(), 3);
        assert_eq!(d.lookup(2), Some(DirEntry { id: 2, shard: 1, slot: 0 }));
        assert_eq!(d.lookup(9), None);
        let ids: Vec<u64> = d.sorted().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn router_routes_and_counts() {
        let r = TrialRouter::default();
        assert!(r.is_empty());
        for id in 1..=100u64 {
            r.insert(id, (id % 7) as usize);
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.get(42), Some(0));
        assert_eq!(r.get(43), Some(1));
        assert_eq!(r.get(999), None);
    }

    #[test]
    fn router_concurrent_inserts_all_visible() {
        let r = std::sync::Arc::new(TrialRouter::default());
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        let id = t * 1000 + i;
                        r.insert(id, (id % 4) as usize);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.len(), 800);
        assert_eq!(r.get(7 * 1000 + 99), Some(((7 * 1000 + 99) % 4) as usize));
    }
}

//! Search-space model.
//!
//! A study's search space is an ordered set of named parameter
//! distributions, mirroring Optuna's `suggest_*` families (the paper's
//! backend): continuous uniform, log-uniform, (log-)integer, and
//! categorical. The wire form follows the HOPAAS Python client's
//! `properties` convention: each parameter is either a `[low, high]`
//! range object with an optional type, or a list of categorical choices.

use crate::json::Value;
use crate::rng::Rng;
use std::fmt;

/// One parameter's distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    /// Continuous uniform on `[low, high]`.
    Uniform { low: f64, high: f64 },
    /// Log-uniform on `[low, high]`, `low > 0`.
    LogUniform { low: f64, high: f64 },
    /// Integer-uniform on `[low, high]` inclusive.
    Int { low: i64, high: i64 },
    /// Categorical over explicit choices.
    Cat { choices: Vec<Value> },
}

/// A named parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub name: String,
    pub dist: Dist,
}

/// An ordered search space.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Space {
    pub params: Vec<Param>,
}

/// A concrete assignment of values to every parameter, in space order.
pub type Assignment = Vec<(String, Value)>;

/// Optimization direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Minimize,
    Maximize,
}

impl Direction {
    pub fn from_str(s: &str) -> Option<Direction> {
        match s {
            "minimize" => Some(Direction::Minimize),
            "maximize" => Some(Direction::Maximize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Direction::Minimize => "minimize",
            Direction::Maximize => "maximize",
        }
    }

    /// `true` if `a` is a better score than `b` in this direction.
    pub fn better(&self, a: f64, b: f64) -> bool {
        match self {
            Direction::Minimize => a < b,
            Direction::Maximize => a > b,
        }
    }
}

/// Space validation / wire-format errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SpaceError {
    #[error("parameter '{0}': {1}")]
    Invalid(String, String),
    #[error("malformed search space: {0}")]
    Malformed(String),
}

impl Space {
    /// Parse the `properties` object of an `ask` body.
    ///
    /// Accepted parameter forms:
    /// * `{"low": 0.1, "high": 1.0}` — uniform
    /// * `{"low": 1e-5, "high": 1e-1, "type": "loguniform"}`
    /// * `{"low": 1, "high": 8, "type": "int"}`
    /// * `["adam", "rmsprop"]` or `{"choices": [...]}` — categorical
    /// * a bare scalar — fixed (categorical with one choice)
    pub fn from_json(props: &Value) -> Result<Space, SpaceError> {
        let obj = props
            .as_obj()
            .ok_or_else(|| SpaceError::Malformed("properties must be an object".into()))?;
        let mut params = Vec::new();
        for (name, spec) in obj.iter() {
            let dist = Self::dist_from_json(name, spec)?;
            params.push(Param { name: name.to_string(), dist });
        }
        if params.is_empty() {
            return Err(SpaceError::Malformed("empty search space".into()));
        }
        Ok(Space { params })
    }

    fn dist_from_json(name: &str, spec: &Value) -> Result<Dist, SpaceError> {
        let err = |m: &str| SpaceError::Invalid(name.to_string(), m.to_string());
        match spec {
            Value::Arr(choices) => {
                if choices.is_empty() {
                    return Err(err("empty categorical choices"));
                }
                Ok(Dist::Cat { choices: choices.clone() })
            }
            Value::Obj(o) => {
                if let Some(ch) = o.get("choices") {
                    let choices = ch
                        .as_arr()
                        .ok_or_else(|| err("'choices' must be an array"))?;
                    if choices.is_empty() {
                        return Err(err("empty categorical choices"));
                    }
                    return Ok(Dist::Cat { choices: choices.to_vec() });
                }
                let low = o
                    .get("low")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| err("missing numeric 'low'"))?;
                let high = o
                    .get("high")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| err("missing numeric 'high'"))?;
                if !(low < high) {
                    return Err(err("'low' must be < 'high'"));
                }
                let ty = o.get("type").and_then(Value::as_str).unwrap_or("uniform");
                match ty {
                    "uniform" | "float" => Ok(Dist::Uniform { low, high }),
                    "loguniform" | "log" => {
                        if low <= 0.0 {
                            return Err(err("loguniform requires low > 0"));
                        }
                        Ok(Dist::LogUniform { low, high })
                    }
                    "int" | "integer" => {
                        if low.fract() != 0.0 || high.fract() != 0.0 {
                            return Err(err("int bounds must be integers"));
                        }
                        Ok(Dist::Int { low: low as i64, high: high as i64 })
                    }
                    other => Err(err(&format!("unknown type '{other}'"))),
                }
            }
            // A bare scalar pins the parameter.
            v @ (Value::Num(_) | Value::Str(_) | Value::Bool(_)) => {
                Ok(Dist::Cat { choices: vec![v.clone()] })
            }
            _ => Err(err("unsupported parameter spec")),
        }
    }

    /// Serialize back to the wire form (canonical: used for study hashing).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        for p in &self.params {
            let spec = match &p.dist {
                Dist::Uniform { low, high } => {
                    let mut s = Value::obj();
                    s.set("low", *low).set("high", *high).set("type", "uniform");
                    Value::Obj(s)
                }
                Dist::LogUniform { low, high } => {
                    let mut s = Value::obj();
                    s.set("low", *low).set("high", *high).set("type", "loguniform");
                    Value::Obj(s)
                }
                Dist::Int { low, high } => {
                    let mut s = Value::obj();
                    s.set("low", *low).set("high", *high).set("type", "int");
                    Value::Obj(s)
                }
                Dist::Cat { choices } => Value::Arr(choices.clone()),
            };
            o.set(p.name.as_str(), spec);
        }
        Value::Obj(o)
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Uniform random assignment (the base sampler and TPE's startup).
    pub fn sample(&self, rng: &mut Rng) -> Assignment {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.dist.sample(rng)))
            .collect()
    }

    /// Check a value lies in a parameter's domain.
    pub fn contains(&self, name: &str, value: &Value) -> bool {
        self.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.dist.contains(value))
            .unwrap_or(false)
    }

    /// Map an assignment into the unit hypercube for numeric params
    /// (used by TPE/GP). Categorical params map to their choice index
    /// scaled to [0,1). Returns None if the assignment is incomplete.
    pub fn to_unit(&self, asg: &Assignment) -> Option<Vec<f64>> {
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let v = asg.iter().find(|(n, _)| n == &p.name).map(|(_, v)| v)?;
            out.push(p.dist.to_unit(v)?);
        }
        Some(out)
    }

    /// Inverse of [`Space::to_unit`].
    pub fn from_unit(&self, u: &[f64]) -> Assignment {
        self.params
            .iter()
            .zip(u)
            .map(|(p, &x)| (p.name.clone(), p.dist.from_unit(x.clamp(0.0, 1.0 - 1e-12))))
            .collect()
    }
}

impl Dist {
    /// Uniform draw from this distribution.
    pub fn sample(&self, rng: &mut Rng) -> Value {
        match self {
            Dist::Uniform { low, high } => Value::Num(rng.uniform(*low, *high)),
            Dist::LogUniform { low, high } => {
                Value::Num((rng.uniform(low.ln(), high.ln())).exp())
            }
            Dist::Int { low, high } => Value::Num(rng.int_range(*low, *high) as f64),
            Dist::Cat { choices } => choices[rng.below(choices.len() as u64) as usize].clone(),
        }
    }

    /// Domain membership.
    pub fn contains(&self, v: &Value) -> bool {
        match self {
            Dist::Uniform { low, high } | Dist::LogUniform { low, high } => v
                .as_f64()
                .map(|x| x >= *low && x <= *high)
                .unwrap_or(false),
            Dist::Int { low, high } => v
                .as_i64()
                .map(|x| x >= *low && x <= *high)
                .unwrap_or(false),
            Dist::Cat { choices } => choices.contains(v),
        }
    }

    /// Map a value to [0, 1).
    pub fn to_unit(&self, v: &Value) -> Option<f64> {
        match self {
            Dist::Uniform { low, high } => {
                let x = v.as_f64()?;
                Some(((x - low) / (high - low)).clamp(0.0, 1.0))
            }
            Dist::LogUniform { low, high } => {
                let x = v.as_f64()?;
                if x <= 0.0 {
                    return None;
                }
                Some(((x.ln() - low.ln()) / (high.ln() - low.ln())).clamp(0.0, 1.0))
            }
            Dist::Int { low, high } => {
                let x = v.as_i64()? as f64;
                let span = (*high - *low) as f64 + 1.0;
                Some(((x - *low as f64 + 0.5) / span).clamp(0.0, 1.0))
            }
            Dist::Cat { choices } => {
                let idx = choices.iter().position(|c| c == v)? as f64;
                Some((idx + 0.5) / choices.len() as f64)
            }
        }
    }

    /// Map a unit value back into the domain.
    pub fn from_unit(&self, u: f64) -> Value {
        match self {
            Dist::Uniform { low, high } => Value::Num(low + u * (high - low)),
            Dist::LogUniform { low, high } => {
                Value::Num((low.ln() + u * (high.ln() - low.ln())).exp())
            }
            Dist::Int { low, high } => {
                let span = (*high - *low) as f64 + 1.0;
                let x = (*low as f64 + u * span).floor();
                Value::Num(x.clamp(*low as f64, *high as f64))
            }
            Dist::Cat { choices } => {
                let idx = ((u * choices.len() as f64).floor() as usize).min(choices.len() - 1);
                choices[idx].clone()
            }
        }
    }

    /// Number of categories, if categorical.
    pub fn n_choices(&self) -> Option<usize> {
        match self {
            Dist::Cat { choices } => Some(choices.len()),
            _ => None,
        }
    }
}

/// Serialize an assignment as a JSON object (in space order).
pub fn assignment_to_json(asg: &Assignment) -> Value {
    let mut o = Value::obj();
    for (k, v) in asg {
        o.set(k.as_str(), v.clone());
    }
    Value::Obj(o)
}

impl fmt::Display for Dist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dist::Uniform { low, high } => write!(f, "uniform[{low}, {high}]"),
            Dist::LogUniform { low, high } => write!(f, "loguniform[{low}, {high}]"),
            Dist::Int { low, high } => write!(f, "int[{low}, {high}]"),
            Dist::Cat { choices } => write!(f, "cat({} choices)", choices.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::testutil::prop;

    fn space() -> Space {
        Space::from_json(
            &parse(
                r#"{
                "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
                "dropout": {"low": 0.0, "high": 0.5},
                "layers": {"low": 1, "high": 8, "type": "int"},
                "opt": ["adam", "rmsprop", "sgd"]
            }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn parse_all_kinds() {
        let s = space();
        assert_eq!(s.len(), 4);
        assert!(matches!(s.params[0].dist, Dist::LogUniform { .. }));
        assert!(matches!(s.params[1].dist, Dist::Uniform { .. }));
        assert!(matches!(s.params[2].dist, Dist::Int { low: 1, high: 8 }));
        assert!(matches!(s.params[3].dist, Dist::Cat { .. }));
    }

    #[test]
    fn parse_errors() {
        for bad in [
            r#"{"x": {"low": 2, "high": 1}}"#,
            r#"{"x": {"low": 0, "high": 1, "type": "loguniform"}}"#,
            r#"{"x": {"low": 0.5, "high": 1.5, "type": "int"}}"#,
            r#"{"x": []}"#,
            r#"{"x": {"high": 1}}"#,
            r#"{"x": {"low": 0, "high": 1, "type": "wat"}}"#,
            r#"{}"#,
            r#"[1,2]"#,
        ] {
            assert!(
                Space::from_json(&parse(bad).unwrap()).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn scalar_pins_parameter() {
        let s = Space::from_json(&parse(r#"{"batch": 256}"#).unwrap()).unwrap();
        let mut rng = Rng::new(1);
        let asg = s.sample(&mut rng);
        assert_eq!(asg[0].1.as_i64(), Some(256));
    }

    #[test]
    fn samples_in_domain() {
        let s = space();
        prop::check(200, |g| {
            let asg = s.sample(g.rng());
            for (name, v) in &asg {
                if !s.contains(name, v) {
                    return Err(format!("{name}={v} out of domain"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn loguniform_spans_decades() {
        let s = space();
        let mut rng = Rng::new(5);
        let mut low_decade = 0;
        let mut high_decade = 0;
        for _ in 0..2000 {
            let asg = s.sample(&mut rng);
            let lr = asg[0].1.as_f64().unwrap();
            if lr < 1e-4 {
                low_decade += 1;
            }
            if lr > 1e-2 {
                high_decade += 1;
            }
        }
        // Log-uniform: each decade ≈ 25% of mass.
        assert!(low_decade > 300, "low decade {low_decade}");
        assert!(high_decade > 300, "high decade {high_decade}");
    }

    #[test]
    fn unit_roundtrip() {
        let s = space();
        prop::check(200, |g| {
            let asg = s.sample(g.rng());
            let u = s.to_unit(&asg).ok_or("to_unit failed")?;
            let back = s.from_unit(&u);
            for ((n1, v1), (n2, v2)) in asg.iter().zip(&back) {
                if n1 != n2 {
                    return Err("name order changed".into());
                }
                match (v1.as_f64(), v2.as_f64()) {
                    (Some(a), Some(b)) => {
                        let rel = (a - b).abs() / a.abs().max(1e-12);
                        if rel > 1e-9 && (a - b).abs() > 1e-9 {
                            return Err(format!("{n1}: {a} vs {b}"));
                        }
                    }
                    _ => {
                        if v1 != v2 {
                            return Err(format!("{n1}: {v1} vs {v2}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn canonical_json_stable() {
        let s = space();
        let j1 = s.to_json().to_string();
        let s2 = Space::from_json(&parse(&j1).unwrap()).unwrap();
        assert_eq!(s, s2);
        assert_eq!(j1, s2.to_json().to_string());
    }

    #[test]
    fn direction_better() {
        assert!(Direction::Minimize.better(1.0, 2.0));
        assert!(Direction::Maximize.better(2.0, 1.0));
        assert!(!Direction::Minimize.better(2.0, 1.0));
    }

    #[test]
    fn int_to_unit_from_unit_consistent() {
        let d = Dist::Int { low: -2, high: 2 };
        for v in -2..=2 {
            let u = d.to_unit(&Value::Num(v as f64)).unwrap();
            assert_eq!(d.from_unit(u).as_i64(), Some(v));
        }
    }

    #[test]
    fn cat_to_unit_from_unit_consistent() {
        let d = Dist::Cat {
            choices: vec![Value::Str("a".into()), Value::Str("b".into()), Value::Str("c".into())],
        };
        for c in ["a", "b", "c"] {
            let v = Value::Str(c.into());
            let u = d.to_unit(&v).unwrap();
            assert_eq!(d.from_unit(u), v);
        }
    }
}

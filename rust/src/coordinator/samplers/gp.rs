//! Gaussian-process Bayesian optimization with expected improvement.
//!
//! The classic surrogate-model description of the paper's §1: fit a GP to
//! `(x, y)` history on the unit cube, then propose the candidate
//! maximizing expected improvement over the incumbent. Complements TPE
//! in the sampler study (E4).
//!
//! Model:
//! * Matérn-5/2 kernel with a shared length scale, unit signal variance,
//!   plus observation noise — hyperparameters chosen per-suggestion by
//!   log-marginal-likelihood over a small grid (cheap and robust, avoids
//!   an optimizer-in-the-optimizer);
//! * values standardized to zero mean / unit variance;
//! * EI maximized over quasi-random candidates plus Gaussian
//!   perturbations of the incumbent (exploit local basin);
//! * falls back to uniform sampling until `n_startup_trials`
//!   observations exist, and caps the conditioning set at the most
//!   recent `max_obs` points (O(n³) Cholesky).

use super::super::space::{Assignment, Direction, Space};
use super::super::study::AlgoConfig;
use super::{unit_history, FitState, Obs, Sampler};
use crate::linalg::{cholesky, norm_cdf, norm_pdf, Mat};
use crate::rng::Rng;

/// GP-EI sampler.
pub struct GpSampler {
    pub n_startup_trials: u64,
    pub n_candidates: usize,
    pub max_obs: usize,
}

impl GpSampler {
    pub fn from_config(cfg: &AlgoConfig) -> GpSampler {
        GpSampler {
            n_startup_trials: cfg.u64_opt("n_startup_trials", 10),
            n_candidates: cfg.u64_opt("n_candidates", 256) as usize,
            max_obs: cfg.u64_opt("max_obs", 256) as usize,
        }
    }
}

/// Matérn-5/2 correlation for distance `r` and length scale `l`.
#[inline]
fn matern52(r: f64, l: f64) -> f64 {
    let s = (5.0_f64).sqrt() * r / l;
    (1.0 + s + s * s / 3.0) * (-s).exp()
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// A fitted GP posterior.
struct Posterior {
    xs: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    chol: crate::linalg::Chol,
    length: f64,
    y_mean: f64,
    y_std: f64,
}

impl Posterior {
    /// Fit with hyperparameters selected by log marginal likelihood.
    fn fit(xs: Vec<Vec<f64>>, ys: &[f64]) -> Option<Posterior> {
        let n = xs.len();
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let var = ys.iter().map(|y| (y - y_mean) * (y - y_mean)).sum::<f64>() / n as f64;
        let y_std = var.sqrt().max(1e-12);
        let yn: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();

        let mut best: Option<(f64, crate::linalg::Chol, Vec<f64>, f64)> = None;
        for &length in &[0.1, 0.2, 0.4, 0.8] {
            for &noise in &[1e-6, 1e-4, 1e-2] {
                let mut k = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..=i {
                        let v = matern52(sq_dist(&xs[i], &xs[j]).sqrt(), length);
                        *k.at_mut(i, j) = v;
                        *k.at_mut(j, i) = v;
                    }
                    *k.at_mut(i, i) += noise + 1e-9;
                }
                let Ok(chol) = cholesky(&k) else { continue };
                let alpha = chol.solve(&yn);
                // log p(y) = -½ yᵀα − ½ log det K − (n/2) log 2π
                let lml = -0.5 * yn.iter().zip(&alpha).map(|(y, a)| y * a).sum::<f64>()
                    - 0.5 * chol.log_det()
                    - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
                if best.as_ref().map_or(true, |(b, _, _, _)| lml > *b) {
                    best = Some((lml, chol, alpha, length));
                }
            }
        }
        let (_, chol, alpha, length) = best?;
        Some(Posterior { xs, alpha, chol, length, y_mean, y_std })
    }

    /// Predictive mean and std at `x` (original y units).
    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let kx: Vec<f64> = self
            .xs
            .iter()
            .map(|xi| matern52(sq_dist(xi, x).sqrt(), self.length))
            .collect();
        let mean_n: f64 = kx.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let v = self.chol.forward(&kx);
        let var_n = (1.0 - v.iter().map(|t| t * t).sum::<f64>()).max(1e-12);
        (self.y_mean + self.y_std * mean_n, self.y_std * var_n.sqrt())
    }
}

/// Expected improvement (minimization orientation).
fn expected_improvement(mean: f64, std: f64, incumbent: f64) -> f64 {
    if std <= 0.0 {
        return (incumbent - mean).max(0.0);
    }
    let z = (incumbent - mean) / std;
    (incumbent - mean) * norm_cdf(z) + std * norm_pdf(z)
}

/// Fitted GP state: the conditioning-set factorization (Cholesky of the
/// kernel matrix + dual weights) plus the incumbent. RNG-free — the
/// length-scale/noise grid search is deterministic in the history.
pub struct GpFit {
    startup: bool,
    post: Option<Posterior>,
    incumbent: f64,
    inc_x: Vec<f64>,
}

impl FitState for GpFit {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl Sampler for GpSampler {
    fn name(&self) -> &'static str {
        "gp"
    }

    fn fit(&self, space: &Space, obs: &[Obs], direction: Direction) -> Box<dyn FitState> {
        let (mut xs, mut ys) = unit_history(space, obs, direction);
        if (xs.len() as u64) < self.n_startup_trials {
            return Box::new(GpFit {
                startup: true,
                post: None,
                incumbent: f64::INFINITY,
                inc_x: Vec::new(),
            });
        }
        // Cap conditioning set: keep the most recent points.
        if xs.len() > self.max_obs {
            let skip = xs.len() - self.max_obs;
            xs.drain(..skip);
            ys.drain(..skip);
        }
        let Some(post) = Posterior::fit(xs, &ys) else {
            return Box::new(GpFit {
                startup: false,
                post: None,
                incumbent: f64::INFINITY,
                inc_x: Vec::new(),
            });
        };
        let incumbent = ys.iter().copied().fold(f64::INFINITY, f64::min);
        let (inc_idx, _) = ys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let inc_x = post.xs[inc_idx].clone();
        Box::new(GpFit { startup: false, post: Some(post), incumbent, inc_x })
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        fit: &dyn FitState,
        _n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        let Some(f) = fit.as_any().downcast_ref::<GpFit>() else {
            return space.sample(rng);
        };
        if f.startup {
            return space.sample(rng);
        }
        let Some(post) = &f.post else {
            return space.sample(rng);
        };
        let d = space.len();

        let mut best: Option<(f64, Vec<f64>)> = None;
        let n_global = self.n_candidates.max(8);
        let n_local = (n_global / 4).max(4);
        for i in 0..n_global + n_local {
            let cand: Vec<f64> = if i < n_global {
                (0..d).map(|_| rng.f64()).collect()
            } else {
                // Local perturbations of the incumbent.
                f.inc_x
                    .iter()
                    .map(|&x| (x + rng.normal() * 0.05).clamp(0.0, 1.0 - 1e-12))
                    .collect()
            };
            let (m, s) = post.predict(&cand);
            let ei = expected_improvement(m, s, f.incumbent);
            if best.as_ref().map_or(true, |(b, _)| ei > *b) {
                best = Some((ei, cand));
            }
        }
        match best {
            Some((_, u)) => space.from_unit(&u),
            None => space.sample(rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn space1d() -> Space {
        Space::from_json(&parse(r#"{"x": {"low": 0.0, "high": 1.0}}"#).unwrap()).unwrap()
    }

    fn obs_at(x: f64, v: f64) -> Obs {
        Obs { params: vec![("x".into(), crate::json::Value::Num(x))], value: v }
    }

    #[test]
    fn matern_properties() {
        assert!((matern52(0.0, 0.3) - 1.0).abs() < 1e-12);
        assert!(matern52(0.1, 0.3) > matern52(0.5, 0.3));
        assert!(matern52(10.0, 0.3) < 1e-6);
    }

    #[test]
    fn posterior_interpolates() {
        let xs = vec![vec![0.1], vec![0.5], vec![0.9]];
        let ys = vec![1.0, -1.0, 2.0];
        let p = Posterior::fit(xs, &ys).unwrap();
        for (x, y) in [(0.1, 1.0), (0.5, -1.0), (0.9, 2.0)] {
            let (m, s) = p.predict(&[x]);
            assert!((m - y).abs() < 0.1, "mean at {x}: {m} vs {y}");
            assert!(s < 0.5, "std at data point: {s}");
        }
        // Far from data: higher uncertainty than at data.
        let (_, s_far) = p.predict(&[0.3]);
        let (_, s_near) = p.predict(&[0.5]);
        assert!(s_far > s_near);
    }

    #[test]
    fn ei_monotone_in_mean() {
        let e1 = expected_improvement(0.0, 1.0, 1.0);
        let e2 = expected_improvement(0.5, 1.0, 1.0);
        assert!(e1 > e2);
        // Zero std, worse than incumbent: no improvement.
        assert_eq!(expected_improvement(2.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn concentrates_near_minimum() {
        let gp = GpSampler::from_config(&AlgoConfig::new("gp"));
        let s = space1d();
        let mut rng = Rng::new(11);
        let mut obs = Vec::new();
        for i in 0..25 {
            let x = i as f64 / 24.0;
            obs.push(obs_at(x, (x - 0.7) * (x - 0.7)));
        }
        let n = 60;
        let close = (0..n)
            .filter(|_| {
                let x = gp.suggest(&s, &obs, Direction::Minimize, 25, &mut rng)[0]
                    .1
                    .as_f64()
                    .unwrap();
                (x - 0.7).abs() < 0.2
            })
            .count();
        assert!(close > n * 6 / 10, "GP focus: {close}/{n} near 0.7");
    }

    #[test]
    fn startup_uniform_and_domain_respected() {
        let gp = GpSampler::from_config(&AlgoConfig::new("gp"));
        let s = Space::from_json(
            &parse(r#"{"lr": {"low": 1e-4, "high": 1.0, "type": "loguniform"}, "c": ["u","v"]}"#)
                .unwrap(),
        )
        .unwrap();
        crate::testutil::prop::check(30, |g| {
            let n = g.usize(0, 30);
            let obs: Vec<Obs> = (0..n)
                .map(|_| Obs { params: s.sample(g.rng()), value: g.f64(0.0, 1.0) })
                .collect();
            let a = gp.suggest(&s, &obs, Direction::Minimize, n as u64, g.rng());
            for (name, v) in &a {
                if !s.contains(name, v) {
                    return Err(format!("{name}={v} out of domain"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn caps_history() {
        let gp = GpSampler {
            n_startup_trials: 5,
            n_candidates: 16,
            max_obs: 20,
        };
        let s = space1d();
        let mut rng = Rng::new(2);
        let obs: Vec<Obs> = (0..200)
            .map(|i| obs_at((i % 100) as f64 / 100.0, (i % 7) as f64))
            .collect();
        // Must not blow up on 200 points (capped to 20) and returns valid.
        let a = gp.suggest(&s, &obs, Direction::Minimize, 200, &mut rng);
        assert!(s.contains("x", &a[0].1));
    }
}

//! NSGA-II-style multi-objective sampler (Deb et al. 2002), adapted to
//! HOPAAS's asynchronous ask/tell protocol: instead of lock-step
//! generations, each suggestion re-derives the parent population from
//! the most recent window of completed trials — the same
//! stateless-from-history design as the other samplers, so recovery and
//! multi-node campaigns need no sampler state.
//!
//! Per suggestion:
//! 1. window = last `2·pop_size` multi-valued observations (unit cube);
//! 2. rank by fast non-dominated sort + crowding distance;
//! 3. two parents by binary tournament (rank, then crowding);
//! 4. SBX crossover (η_c = 15, p = 0.9) + polynomial mutation
//!    (η_m = 20, p = 1/d) per dimension;
//! 5. clamp to the cube and map back to the search space.

use super::super::mo::{crowding_distance, non_dominated_sort, orient};
use super::super::space::{Assignment, Direction, Space};
use super::super::study::AlgoConfig;
use crate::rng::Rng;

/// A multi-objective observation.
#[derive(Clone, Debug)]
pub struct MoObs {
    pub params: Assignment,
    pub values: Vec<f64>,
}

/// NSGA-II sampler configuration.
pub struct Nsga2Sampler {
    pub pop_size: usize,
    pub crossover_eta: f64,
    pub crossover_prob: f64,
    pub mutation_eta: f64,
}

impl Nsga2Sampler {
    pub fn from_config(cfg: &AlgoConfig) -> Nsga2Sampler {
        Nsga2Sampler {
            pop_size: cfg.u64_opt("pop_size", 24) as usize,
            crossover_eta: cfg.f64_opt("crossover_eta", 15.0),
            crossover_prob: cfg.f64_opt("crossover_prob", 0.9),
            mutation_eta: cfg.f64_opt("mutation_eta", 20.0),
        }
    }

    /// Suggest the next point for a multi-objective study.
    pub fn suggest_mo(
        &self,
        space: &Space,
        obs: &[MoObs],
        directions: &[Direction],
        rng: &mut Rng,
    ) -> Assignment {
        let usable: Vec<&MoObs> = obs
            .iter()
            .filter(|o| {
                o.values.len() == directions.len() && o.values.iter().all(|v| v.is_finite())
            })
            .collect();
        if usable.len() < self.pop_size.max(4) {
            return space.sample(rng);
        }
        // Window of the most recent 2·pop.
        let window = (2 * self.pop_size).min(usable.len());
        let pop = &usable[usable.len() - window..];

        let xs: Vec<Vec<f64>> = pop
            .iter()
            .filter_map(|o| space.to_unit(&o.params))
            .collect();
        if xs.len() < 4 {
            return space.sample(rng);
        }
        let ys: Vec<Vec<f64>> = pop.iter().map(|o| orient(&o.values, directions)).collect();

        // Rank + crowding over the window.
        let fronts = non_dominated_sort(&ys);
        let mut rank = vec![usize::MAX; ys.len()];
        let mut crowd = vec![0.0f64; ys.len()];
        for (r, front) in fronts.iter().enumerate() {
            let d = crowding_distance(&ys, front);
            for (&i, &di) in front.iter().zip(&d) {
                rank[i] = r;
                crowd[i] = di;
            }
        }

        let tournament = |rng: &mut Rng| -> usize {
            let a = rng.below(xs.len() as u64) as usize;
            let b = rng.below(xs.len() as u64) as usize;
            if rank[a] < rank[b] || (rank[a] == rank[b] && crowd[a] > crowd[b]) {
                a
            } else {
                b
            }
        };
        let p1 = &xs[tournament(rng)];
        let p2 = &xs[tournament(rng)];

        let d = space.len();
        let mut child = Vec::with_capacity(d);
        let do_crossover = rng.chance(self.crossover_prob);
        for k in 0..d {
            let (x1, x2) = (p1[k], p2[k]);
            // SBX crossover.
            let mut c = if do_crossover {
                let u = rng.f64();
                let beta = if u <= 0.5 {
                    (2.0 * u).powf(1.0 / (self.crossover_eta + 1.0))
                } else {
                    (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (self.crossover_eta + 1.0))
                };
                if rng.chance(0.5) {
                    0.5 * ((1.0 + beta) * x1 + (1.0 - beta) * x2)
                } else {
                    0.5 * ((1.0 - beta) * x1 + (1.0 + beta) * x2)
                }
            } else {
                x1
            };
            // Polynomial mutation with probability 1/d.
            if rng.chance(1.0 / d as f64) {
                let u = rng.f64();
                let delta = if u < 0.5 {
                    (2.0 * u).powf(1.0 / (self.mutation_eta + 1.0)) - 1.0
                } else {
                    1.0 - (2.0 * (1.0 - u)).powf(1.0 / (self.mutation_eta + 1.0))
                };
                c += delta;
            }
            child.push(c.clamp(0.0, 1.0 - 1e-12));
        }
        space.from_unit(&child)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn space2d() -> Space {
        Space::from_json(
            &parse(r#"{"x": {"low": 0.0, "high": 1.0}, "y": {"low": 0.0, "high": 1.0}}"#).unwrap(),
        )
        .unwrap()
    }

    fn sampler() -> Nsga2Sampler {
        Nsga2Sampler::from_config(&AlgoConfig::new("nsga2"))
    }

    /// Simple bi-objective: f1 = x, f2 = 1 - x + y (trade-off along x,
    /// y should go to 0).
    fn eval(asg: &Assignment) -> Vec<f64> {
        let x = asg[0].1.as_f64().unwrap();
        let y = asg[1].1.as_f64().unwrap();
        vec![x, 1.0 - x + y]
    }

    #[test]
    fn random_until_population() {
        let s = sampler();
        let sp = space2d();
        let mut rng = Rng::new(1);
        let a = s.suggest_mo(&sp, &[], &[Direction::Minimize, Direction::Minimize], &mut rng);
        assert!(sp.contains("x", &a[0].1));
    }

    #[test]
    fn drives_y_to_zero() {
        // On f = (x, 1-x+y), all Pareto-optimal points have y = 0. After
        // a few "generations" NSGA-II should propose low y far more often
        // than uniform.
        let s = sampler();
        let sp = space2d();
        let mut rng = Rng::new(7);
        let dirs = [Direction::Minimize, Direction::Minimize];
        let mut obs: Vec<MoObs> = Vec::new();
        // Seed random, then iterate suggest→evaluate.
        for _ in 0..30 {
            let a = sp.sample(&mut rng);
            let v = eval(&a);
            obs.push(MoObs { params: a, values: v });
        }
        for _ in 0..120 {
            let a = s.suggest_mo(&sp, &obs, &dirs, &mut rng);
            let v = eval(&a);
            obs.push(MoObs { params: a, values: v });
        }
        let last50: Vec<f64> = obs[obs.len() - 50..]
            .iter()
            .map(|o| o.params[1].1.as_f64().unwrap())
            .collect();
        let mean_y = last50.iter().sum::<f64>() / last50.len() as f64;
        assert!(mean_y < 0.25, "mean y of late proposals = {mean_y} (uniform would be 0.5)");
    }

    #[test]
    fn domain_respected_and_handles_bad_values() {
        let s = sampler();
        let sp = space2d();
        crate::testutil::prop::check(50, |g| {
            let dirs = [Direction::Minimize, Direction::Maximize];
            let mut obs = Vec::new();
            for i in 0..g.usize(0, 60) {
                let a = sp.sample(g.rng());
                let values = if i % 7 == 0 {
                    vec![f64::NAN, 1.0] // rejected
                } else if i % 11 == 0 {
                    vec![1.0] // wrong arity, rejected
                } else {
                    vec![g.f64(0.0, 1.0), g.f64(0.0, 1.0)]
                };
                obs.push(MoObs { params: a, values });
            }
            let a = s.suggest_mo(&sp, &obs, &dirs, g.rng());
            for (n, v) in &a {
                if !sp.contains(n, v) {
                    return Err(format!("{n}={v}"));
                }
            }
            Ok(())
        });
    }
}

//! Tree-structured Parzen Estimator sampler.
//!
//! Reproduces Optuna's default (univariate) TPE [Bergstra et al. 2011;
//! Akiba et al. 2019] — the algorithm behind the paper's optimization
//! backend:
//!
//! 1. Until `n_startup_trials` observations exist, sample uniformly.
//! 2. Split observations into *good* (the best `γ(n)` by objective) and
//!    *bad* (the rest), with Optuna's default `γ(n) = min(⌈0.1·n⌉, 25)`.
//! 3. Per parameter, fit Parzen estimators `l(x)` (good) and `g(x)`
//!    (bad): truncated-Gaussian mixtures on the unit-mapped domain for
//!    numeric parameters (log-uniform handled by the unit map), weighted
//!    category histograms with a unit prior for categoricals. Bandwidths
//!    follow the hyperopt neighbor-distance heuristic with the "magic
//!    clip" lower bound; a uniform prior component regularizes both
//!    mixtures.
//! 4. Draw `n_ei_candidates` (default 24) from `l`, keep the candidate
//!    maximizing `log l(x) − log g(x)` — which is monotone in expected
//!    improvement under the TPE derivation.
//!
//! Pruned trials participate at their last intermediate value, as in
//! Optuna, so pruning sharpens rather than starves the surrogate.

use super::super::space::{Assignment, Direction, Dist, Space};
use super::super::study::AlgoConfig;
use super::{FitState, Obs, Sampler};
use crate::json::Value;
use crate::linalg::{norm_cdf, trunc_mixture_log_pdf, trunc_mixture_log_pdf_many, DensityGrid};
use crate::rng::Rng;

/// Tabulate the bad-mixture log-density on a grid once the component
/// count makes exact per-candidate evaluation the dominant cost. Below
/// this the exact flat loop is both faster and bit-identical to the
/// historical behaviour.
const BAD_GRID_MIN_OBS: usize = 64;

/// TPE with Optuna-default settings.
pub struct TpeSampler {
    pub n_startup_trials: u64,
    pub n_ei_candidates: usize,
    /// Cap on the good-set size: γ(n) = min(⌈gamma_frac·n⌉, gamma_cap).
    pub gamma_frac: f64,
    pub gamma_cap: usize,
    /// Suggest from at most the most recent `max_obs` observations
    /// (§Perf: bounds the per-ask KDE cost at campaign scale; the good
    /// set is capped at 25 anyway, so only the *bad* density loses old
    /// mass — negligible statistically, large operationally).
    pub max_obs: usize,
}

impl TpeSampler {
    pub fn from_config(cfg: &AlgoConfig) -> TpeSampler {
        TpeSampler {
            n_startup_trials: cfg.u64_opt("n_startup_trials", 10),
            n_ei_candidates: cfg.u64_opt("n_ei_candidates", 24) as usize,
            gamma_frac: cfg.f64_opt("gamma", 0.1),
            gamma_cap: cfg.u64_opt("gamma_cap", 25) as usize,
            max_obs: cfg.u64_opt("max_obs", 1024) as usize,
        }
    }

    fn n_good(&self, n: usize) -> usize {
        (((self.gamma_frac * n as f64).ceil() as usize).max(1)).min(self.gamma_cap)
    }
}

impl Sampler for TpeSampler {
    fn name(&self) -> &'static str {
        "tpe"
    }

    fn fit(&self, space: &Space, obs: &[Obs], direction: Direction) -> Box<dyn FitState> {
        let mut finite: Vec<&Obs> = obs.iter().filter(|o| o.value.is_finite()).collect();
        if (finite.len() as u64) < self.n_startup_trials {
            return Box::new(TpeFit { startup: true, estimators: Vec::new() });
        }
        // History window (§Perf): keep only the most recent max_obs.
        if finite.len() > self.max_obs.max(1) {
            let skip = finite.len() - self.max_obs.max(1);
            finite.drain(..skip);
        }

        // Sort by objective, best first (orient for minimization).
        let mut sorted: Vec<&Obs> = finite;
        sorted.sort_by(|a, b| {
            let (x, y) = match direction {
                Direction::Minimize => (a.value, b.value),
                Direction::Maximize => (b.value, a.value),
            };
            x.total_cmp(&y)
        });
        let n_good = self.n_good(sorted.len());
        let (good, bad) = sorted.split_at(n_good);

        // One-pass column extraction (§Perf): route every observed value
        // to its parameter's contiguous column through a name→index map,
        // instead of an O(|params|) scan per (observation, parameter)
        // pair inside each estimator fit.
        let index: std::collections::HashMap<&str, usize> = space
            .params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.as_str(), i))
            .collect();
        let good_cols = param_columns(good, &index, space.params.len());
        let bad_cols = param_columns(bad, &index, space.params.len());
        let estimators: Vec<ParamEstimator> = space
            .params
            .iter()
            .enumerate()
            .map(|(j, p)| ParamEstimator::fit(&p.dist, &good_cols[j], &bad_cols[j]))
            .collect();
        Box::new(TpeFit { startup: false, estimators })
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        fit: &dyn FitState,
        _n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        let Some(f) = fit.as_any().downcast_ref::<TpeFit>() else {
            return space.sample(rng);
        };
        if f.startup {
            return space.sample(rng);
        }
        let k = self.n_ei_candidates.max(1);
        // Draw every candidate first (candidate-outer, parameter-inner —
        // the historical order, so the RNG stream is unchanged), landing
        // the draws in contiguous per-parameter columns; then score each
        // column through the batched mixture evaluation, which streams
        // the (large) bad-mixture arrays once for all candidates instead
        // of once per candidate. Scoring consumes no randomness and the
        // batched kernel is bit-identical to the scalar one, so the
        // chosen candidate matches the per-candidate loop exactly.
        let mut cols: Vec<DrawnColumn> = f
            .estimators
            .iter()
            .map(|est| match est {
                ParamEstimator::Numeric { .. } => DrawnColumn::Num(Vec::with_capacity(k)),
                ParamEstimator::Cat { .. } => DrawnColumn::Cat(Vec::with_capacity(k)),
            })
            .collect();
        for _ in 0..k {
            for (est, col) in f.estimators.iter().zip(cols.iter_mut()) {
                match (est, col) {
                    (ParamEstimator::Numeric { good, .. }, DrawnColumn::Num(us)) => {
                        us.push(good.sample(rng));
                    }
                    (ParamEstimator::Cat { good, .. }, DrawnColumn::Cat(idxs)) => {
                        idxs.push(rng.weighted(good));
                    }
                    _ => unreachable!("column kind fixed by estimator kind"),
                }
            }
        }
        let mut scores = vec![0.0f64; k];
        let mut log_l = vec![0.0f64; k];
        let mut log_g = vec![0.0f64; k];
        for (est, col) in f.estimators.iter().zip(&cols) {
            match (est, col) {
                (ParamEstimator::Numeric { good, bad, bad_grid }, DrawnColumn::Num(us)) => {
                    good.log_pdf_many(us, &mut log_l);
                    match bad_grid {
                        Some(grid) => grid.log_pdf_many(us, &mut log_g),
                        None => bad.log_pdf_many(us, &mut log_g),
                    }
                    for ((sc, &l), &g) in scores.iter_mut().zip(&log_l).zip(&log_g) {
                        *sc += l - g;
                    }
                }
                (ParamEstimator::Cat { good, bad }, DrawnColumn::Cat(idxs)) => {
                    for (sc, &idx) in scores.iter_mut().zip(idxs) {
                        *sc += good[idx].ln() - bad[idx].ln();
                    }
                }
                _ => unreachable!("column kind fixed by estimator kind"),
            }
        }
        // First strict maximum — the per-candidate loop's tie-breaking.
        let mut winner = 0usize;
        for (i, &s) in scores.iter().enumerate().skip(1) {
            if s > scores[winner] {
                winner = i;
            }
        }
        space
            .params
            .iter()
            .zip(&cols)
            .map(|(p, col)| {
                let v = match col {
                    DrawnColumn::Num(us) => p.dist.from_unit(us[winner]),
                    DrawnColumn::Cat(idxs) => {
                        let n = match &p.dist {
                            Dist::Cat { choices } => choices.len(),
                            _ => unreachable!("cat column on non-cat dist"),
                        };
                        p.dist.from_unit((idxs[winner] as f64 + 0.5) / n as f64)
                    }
                };
                (p.name.clone(), v)
            })
            .collect()
    }
}

/// Per-parameter candidate draws, stored as one contiguous column per
/// parameter (unit-interval points for numeric, category indices for
/// categorical) so the batched scorers stream them in one pass.
enum DrawnColumn {
    Num(Vec<f64>),
    Cat(Vec<usize>),
}

/// Split a set of observations into per-parameter value columns in one
/// pass, preserving observation order within each column.
fn param_columns<'a>(
    set: &[&'a Obs],
    index: &std::collections::HashMap<&str, usize>,
    n_params: usize,
) -> Vec<Vec<&'a Value>> {
    let mut cols: Vec<Vec<&'a Value>> =
        (0..n_params).map(|_| Vec::with_capacity(set.len())).collect();
    for o in set {
        for (name, v) in &o.params {
            if let Some(&j) = index.get(name.as_str()) {
                cols[j].push(v);
            }
        }
    }
    cols
}

/// Sufficient statistics of one TPE fit: the per-parameter l/g Parzen
/// estimators (plus the tabulated bad-mixture grid at large histories).
/// Pure function of (space, windowed history, direction) — no RNG — so
/// the engine can cache it per tell-epoch without perturbing the
/// suggestion stream.
pub struct TpeFit {
    startup: bool,
    estimators: Vec<ParamEstimator>,
}

impl FitState for TpeFit {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Fitted l/g estimators for one parameter.
enum ParamEstimator {
    Numeric {
        good: Parzen,
        bad: Parzen,
        /// Grid-tabulated `log g(x)` when the bad set is large; the good
        /// mixture stays exact (≤ `gamma_cap` + 1 components).
        bad_grid: Option<DensityGrid>,
    },
    Cat {
        good: Vec<f64>,
        bad: Vec<f64>,
    },
}

impl ParamEstimator {
    /// Fit from this parameter's contiguous value columns (one slot per
    /// observation that recorded the parameter, in observation order —
    /// the same values the old per-observation scan extracted).
    fn fit(dist: &Dist, good: &[&Value], bad: &[&Value]) -> ParamEstimator {
        match dist {
            Dist::Cat { choices } => {
                let hist = |vals: &[&Value]| -> Vec<f64> {
                    // Unit prior on every category (Laplace smoothing).
                    let mut w = vec![1.0; choices.len()];
                    for v in vals {
                        if let Some(i) = choices.iter().position(|c| c == *v) {
                            w[i] += 1.0;
                        }
                    }
                    let total: f64 = w.iter().sum();
                    w.iter().map(|x| x / total).collect()
                };
                ParamEstimator::Cat { good: hist(good), bad: hist(bad) }
            }
            _ => {
                let unit = |vals: &[&Value]| -> Vec<f64> {
                    vals.iter().filter_map(|v| dist.to_unit(v)).collect()
                };
                let bad = Parzen::fit(&unit(bad));
                let bad_grid = (bad.len() >= BAD_GRID_MIN_OBS)
                    .then(|| bad.density_grid(DensityGrid::DEFAULT_BINS));
                ParamEstimator::Numeric { good: Parzen::fit(&unit(good)), bad, bad_grid }
            }
        }
    }
}

/// Truncated-Gaussian Parzen mixture on [0, 1] with a uniform prior
/// component.
pub struct Parzen {
    /// Component means (prior component handled separately).
    mus: Vec<f64>,
    sigmas: Vec<f64>,
    /// Normalization of each truncated Gaussian on [0,1].
    norms: Vec<f64>,
    /// Mixture weight of each Gaussian; the uniform prior gets the same
    /// weight as one observation.
    w: f64,
}

impl Parzen {
    /// Fit to unit-interval points.
    pub fn fit(points: &[f64]) -> Parzen {
        let mut mus: Vec<f64> = points.iter().copied().filter(|x| x.is_finite()).collect();
        mus.sort_by(f64::total_cmp);
        let n = mus.len();
        // Bandwidths: distance to the farther neighbor (domain edges act
        // as neighbors), clipped below by the "magic clip".
        let sigma_min = 1.0 / (100.0_f64).min((n as f64) + 1.0).max(2.0);
        let sigma_max = 1.0;
        let mut sigmas = Vec::with_capacity(n);
        for i in 0..n {
            let left = if i == 0 { mus[i] - 0.0 } else { mus[i] - mus[i - 1] };
            let right = if i + 1 == n { 1.0 - mus[i] } else { mus[i + 1] - mus[i] };
            let s = left.max(right).clamp(sigma_min, sigma_max);
            sigmas.push(s);
        }
        let norms = mus
            .iter()
            .zip(&sigmas)
            .map(|(&m, &s)| (norm_cdf((1.0 - m) / s) - norm_cdf((0.0 - m) / s)).max(1e-12))
            .collect();
        // n Gaussians + 1 uniform prior, all equally weighted.
        let w = 1.0 / (n as f64 + 1.0);
        Parzen { mus, sigmas, norms, w }
    }

    /// Number of Gaussian components (observations behind this mixture).
    pub fn len(&self) -> usize {
        self.mus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mus.is_empty()
    }

    /// Mixture log-density at `x ∈ [0,1]` — exact flat-slice evaluation.
    pub fn log_pdf(&self, x: f64) -> f64 {
        trunc_mixture_log_pdf(x, &self.mus, &self.sigmas, &self.norms, self.w)
    }

    /// Mixture log-density at many points, streaming the component
    /// arrays once (component-outer). Bit-identical to `log_pdf` per
    /// point — see `linalg::trunc_mixture_log_pdf_many`.
    pub fn log_pdf_many(&self, points: &[f64], out: &mut [f64]) {
        trunc_mixture_log_pdf_many(points, &self.mus, &self.sigmas, &self.norms, self.w, out)
    }

    /// Tabulate the mixture log-density for O(1) interpolated lookups.
    pub fn density_grid(&self, bins: usize) -> DensityGrid {
        DensityGrid::from_trunc_mixture(&self.mus, &self.sigmas, &self.norms, self.w, bins)
    }

    /// Draw one point from the mixture.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let k = rng.below(self.mus.len() as u64 + 1) as usize;
        if k == self.mus.len() {
            return rng.f64(); // prior component
        }
        // Truncated normal by rejection (acceptance ≥ norms[k]).
        for _ in 0..64 {
            let x = rng.normal_ms(self.mus[k], self.sigmas[k]);
            if (0.0..=1.0).contains(&x) {
                return x;
            }
        }
        self.mus[k].clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn space1d() -> Space {
        Space::from_json(&parse(r#"{"x": {"low": 0.0, "high": 1.0}}"#).unwrap()).unwrap()
    }

    fn obs_at(x: f64, v: f64) -> Obs {
        Obs { params: vec![("x".into(), crate::json::Value::Num(x))], value: v }
    }

    #[test]
    fn startup_is_uniform() {
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        let s = space1d();
        let mut rng = Rng::new(1);
        // Only 3 observations (< 10 startup): suggestions spread widely.
        let obs: Vec<Obs> = (0..3).map(|i| obs_at(0.9, i as f64)).collect();
        let xs: Vec<f64> = (0..200)
            .map(|_| {
                tpe.suggest(&s, &obs, Direction::Minimize, 3, &mut rng)[0]
                    .1
                    .as_f64()
                    .unwrap()
            })
            .collect();
        let below_half = xs.iter().filter(|&&x| x < 0.5).count();
        assert!(below_half > 60, "startup not uniform: {below_half}/200 below 0.5");
    }

    #[test]
    fn concentrates_near_good_region() {
        // Objective (x-0.2)²: good observations cluster at 0.2. After 40
        // observations, TPE should propose near 0.2 far more often than
        // uniform would.
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        let s = space1d();
        let mut rng = Rng::new(42);
        let mut obs = Vec::new();
        for _ in 0..40 {
            let x = rng.f64();
            obs.push(obs_at(x, (x - 0.2) * (x - 0.2)));
        }
        let n = 300;
        let close = (0..n)
            .filter(|_| {
                let x = tpe.suggest(&s, &obs, Direction::Minimize, 40, &mut rng)[0]
                    .1
                    .as_f64()
                    .unwrap();
                (x - 0.2).abs() < 0.15
            })
            .count();
        // Uniform would land ~30% in [0.05, 0.35].
        assert!(close > n * 55 / 100, "TPE focus too weak: {close}/{n}");
    }

    #[test]
    fn respects_direction() {
        // Maximize x: good region is near 1.
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        let s = space1d();
        let mut rng = Rng::new(9);
        let mut obs = Vec::new();
        for _ in 0..40 {
            let x = rng.f64();
            obs.push(obs_at(x, x));
        }
        let n = 200;
        let high = (0..n)
            .filter(|_| {
                let x = tpe.suggest(&s, &obs, Direction::Maximize, 40, &mut rng)[0]
                    .1
                    .as_f64()
                    .unwrap();
                x > 0.7
            })
            .count();
        assert!(high > n / 2, "maximize focus: {high}/{n} above 0.7");
    }

    #[test]
    fn categorical_prefers_winning_choice() {
        let s = Space::from_json(&parse(r#"{"c": ["a", "b", "c"]}"#).unwrap()).unwrap();
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        let mut rng = Rng::new(5);
        let mut obs = Vec::new();
        for i in 0..30 {
            let (c, v) = match i % 3 {
                0 => ("a", 0.1),
                1 => ("b", 1.0),
                _ => ("c", 1.0),
            };
            obs.push(Obs {
                params: vec![("c".into(), crate::json::Value::Str(c.into()))],
                value: v + (i as f64) * 1e-4,
            });
        }
        let n = 200;
        let picked_a = (0..n)
            .filter(|_| {
                tpe.suggest(&s, &obs, Direction::Minimize, 30, &mut rng)[0]
                    .1
                    .as_str()
                    == Some("a")
            })
            .count();
        assert!(picked_a > n * 2 / 3, "cat focus: {picked_a}/{n} chose 'a'");
    }

    #[test]
    fn suggestions_stay_in_domain() {
        let s = Space::from_json(
            &parse(
                r#"{
                "lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
                "k": {"low": 2, "high": 7, "type": "int"}
            }"#,
            )
            .unwrap(),
        )
        .unwrap();
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        crate::testutil::prop::check(50, |g| {
            let mut obs = Vec::new();
            for _ in 0..g.usize(10, 40) {
                let a = s.sample(g.rng());
                let v = g.f64(-5.0, 5.0);
                obs.push(Obs { params: a, value: v });
            }
            let a = tpe.suggest(&s, &obs, Direction::Minimize, obs.len() as u64, g.rng());
            for (n, v) in &a {
                if !s.contains(n, v) {
                    return Err(format!("{n}={v} out of domain"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ignores_nonfinite_values() {
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        let s = space1d();
        let mut rng = Rng::new(3);
        let obs: Vec<Obs> = (0..20)
            .map(|i| obs_at(i as f64 / 20.0, if i % 2 == 0 { f64::NAN } else { 1.0 }))
            .collect();
        // 10 finite obs = startup boundary; must not panic.
        let a = tpe.suggest(&s, &obs, Direction::Minimize, 20, &mut rng);
        assert!(s.contains("x", &a[0].1));
    }

    #[test]
    fn parzen_density_integrates_to_one() {
        let p = Parzen::fit(&[0.2, 0.25, 0.8]);
        let n = 20_000;
        let integral: f64 =
            (0..n).map(|i| p.log_pdf((i as f64 + 0.5) / n as f64).exp()).sum::<f64>() / n as f64;
        assert!((integral - 1.0).abs() < 0.01, "integral={integral}");
    }

    #[test]
    fn parzen_peaks_at_data() {
        // With few points the magic clip keeps the KDE deliberately broad
        // (σ_min = 1/(n+1)); with a real cluster the peak is sharp.
        let pts: Vec<f64> = (0..20).map(|i| 0.3 + 0.001 * i as f64).collect();
        let p = Parzen::fit(&pts);
        assert!(p.log_pdf(0.31) > p.log_pdf(0.9) + 1.0);
        // Small-n case: still peaked, just gently.
        let p3 = Parzen::fit(&[0.3, 0.31, 0.29]);
        assert!(p3.log_pdf(0.3) > p3.log_pdf(0.9));
    }

    #[test]
    fn gamma_schedule_matches_optuna() {
        let tpe = TpeSampler::from_config(&AlgoConfig::new("tpe"));
        assert_eq!(tpe.n_good(10), 1);
        assert_eq!(tpe.n_good(20), 2);
        assert_eq!(tpe.n_good(100), 10);
        assert_eq!(tpe.n_good(1000), 25, "capped at 25");
    }
}

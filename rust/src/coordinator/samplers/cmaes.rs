//! Separable CMA-ES-style evolutionary sampler.
//!
//! The paper (§2) lists evolutionary algorithms as a supported search
//! modality. This implements a stateless, ask-and-tell-friendly variant
//! of separable CMA-ES: the sampling distribution is re-derived from the
//! study history on every suggestion, which makes it robust to the
//! asynchronous, multi-node arrival order of HOPAAS trials (classic
//! generation-synchronous CMA-ES assumes a lock-step population; with
//! dozens of opportunistic nodes that structure does not exist).
//!
//! Derivation per suggestion:
//! * rank all observations, keep the top-μ (default λ/2 of the last
//!   generation-equivalent window λ·`window_generations`);
//! * recombination mean = log-rank-weighted mean of the elite, per
//!   dimension (unit cube);
//! * per-dimension variance = weighted elite variance (the "separable"
//!   part — diagonal covariance);
//! * global step size σ decays geometrically with the number of
//!   generation-equivalents completed, from σ₀ (default 0.3), floored at
//!   σ_min — this reproduces CMA-ES's contraction on unimodal
//!   objectives while keeping late-stage exploration alive;
//! * sample N(mean, σ²·diag(var)), clamp to the cube, map back.

use super::super::space::{Assignment, Direction, Space};
use super::super::study::AlgoConfig;
use super::{unit_history, FitState, Obs, Sampler};
use crate::rng::Rng;

/// Separable CMA-ES-style sampler.
pub struct CmaEsSampler {
    /// Population size λ (default `4 + 3·ln(d)` rounded, per Hansen).
    pub lambda: Option<usize>,
    pub sigma0: f64,
    pub sigma_min: f64,
    pub sigma_decay: f64,
    pub window_generations: usize,
}

impl CmaEsSampler {
    pub fn from_config(cfg: &AlgoConfig) -> CmaEsSampler {
        CmaEsSampler {
            lambda: cfg.options.get("lambda").as_u64().map(|v| v as usize),
            sigma0: cfg.f64_opt("sigma0", 0.3),
            sigma_min: cfg.f64_opt("sigma_min", 0.02),
            sigma_decay: cfg.f64_opt("sigma_decay", 0.9),
            window_generations: cfg.u64_opt("window_generations", 3) as usize,
        }
    }

    fn lambda_for(&self, d: usize) -> usize {
        self.lambda
            .unwrap_or_else(|| (4.0 + 3.0 * (d.max(1) as f64).ln()).round() as usize)
            .max(4)
    }
}

/// Fitted CMA-ES distribution state: recombination mean, per-dimension
/// variance, and the decayed global step size. RNG-free derivation.
pub struct CmaFit {
    startup: bool,
    mean: Vec<f64>,
    var: Vec<f64>,
    sigma: f64,
}

impl FitState for CmaFit {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl Sampler for CmaEsSampler {
    fn name(&self) -> &'static str {
        "cmaes"
    }

    fn fit(&self, space: &Space, obs: &[Obs], direction: Direction) -> Box<dyn FitState> {
        let d = space.len();
        let lambda = self.lambda_for(d);
        let (xs, ys) = unit_history(space, obs, direction);
        if xs.len() < lambda {
            return Box::new(CmaFit {
                startup: true,
                mean: Vec::new(),
                var: Vec::new(),
                sigma: self.sigma0,
            });
        }

        // Window: the most recent λ·window observations.
        let window = lambda * self.window_generations.max(1);
        let start = xs.len().saturating_sub(window);
        let xs = &xs[start..];
        let ys = &ys[start..];

        // Elite: top-μ by objective.
        let mu = (lambda / 2).max(2).min(xs.len());
        let mut order: Vec<usize> = (0..xs.len()).collect();
        order.sort_by(|&a, &b| ys[a].total_cmp(&ys[b]));
        let elite: Vec<&Vec<f64>> = order[..mu].iter().map(|&i| &xs[i]).collect();

        // Log-rank recombination weights (Hansen's default shape).
        let raw: Vec<f64> = (0..mu)
            .map(|i| ((mu as f64 + 0.5).ln() - ((i + 1) as f64).ln()).max(0.0))
            .collect();
        let wsum: f64 = raw.iter().sum();
        let w: Vec<f64> = raw.iter().map(|x| x / wsum).collect();

        // Weighted mean + variance per dimension.
        let mut mean = vec![0.0; d];
        for (e, wi) in elite.iter().zip(&w) {
            for k in 0..d {
                mean[k] += wi * e[k];
            }
        }
        let mut var = vec![0.0; d];
        for (e, wi) in elite.iter().zip(&w) {
            for k in 0..d {
                let dv = e[k] - mean[k];
                var[k] += wi * dv * dv;
            }
        }

        // Step size decays with generation-equivalents (keyed on the raw
        // history length, matching the pre-fit-cache behaviour).
        let gens = (obs.len() / lambda) as i32;
        let sigma = (self.sigma0 * self.sigma_decay.powi(gens)).max(self.sigma_min);
        Box::new(CmaFit { startup: false, mean, var, sigma })
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        fit: &dyn FitState,
        _n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        let Some(f) = fit.as_any().downcast_ref::<CmaFit>() else {
            return space.sample(rng);
        };
        if f.startup {
            return space.sample(rng);
        }
        let d = space.len();
        let u: Vec<f64> = (0..d)
            .map(|k| {
                let sd = (f.var[k].sqrt()).max(0.05) * f.sigma / self.sigma0;
                (f.mean[k] + rng.normal() * sd.max(self.sigma_min)).clamp(0.0, 1.0 - 1e-12)
            })
            .collect();
        space.from_unit(&u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn space2d() -> Space {
        Space::from_json(
            &parse(r#"{"x": {"low": 0.0, "high": 1.0}, "y": {"low": 0.0, "high": 1.0}}"#).unwrap(),
        )
        .unwrap()
    }

    fn sphere_obs(space: &Space, rng: &mut Rng, n: usize, cx: f64, cy: f64) -> Vec<Obs> {
        (0..n)
            .map(|_| {
                let a = space.sample(rng);
                let x = a[0].1.as_f64().unwrap();
                let y = a[1].1.as_f64().unwrap();
                Obs { params: a, value: (x - cx).powi(2) + (y - cy).powi(2) }
            })
            .collect()
    }

    #[test]
    fn random_until_lambda() {
        let c = CmaEsSampler::from_config(&AlgoConfig::new("cmaes"));
        let s = space2d();
        let mut rng = Rng::new(1);
        let obs = sphere_obs(&s, &mut rng, 2, 0.5, 0.5);
        // Fewer than λ observations → uniform; check spread.
        let xs: Vec<f64> = (0..100)
            .map(|_| {
                c.suggest(&s, &obs, Direction::Minimize, 2, &mut rng)[0]
                    .1
                    .as_f64()
                    .unwrap()
            })
            .collect();
        assert!(xs.iter().filter(|&&x| x < 0.3).count() > 10);
        assert!(xs.iter().filter(|&&x| x > 0.7).count() > 10);
    }

    #[test]
    fn contracts_toward_elite_mean() {
        let c = CmaEsSampler::from_config(&AlgoConfig::new("cmaes"));
        let s = space2d();
        let mut rng = Rng::new(3);
        let obs = sphere_obs(&s, &mut rng, 80, 0.25, 0.75);
        let n = 200;
        let close = (0..n)
            .filter(|_| {
                let a = c.suggest(&s, &obs, Direction::Minimize, 80, &mut rng);
                let x = a[0].1.as_f64().unwrap();
                let y = a[1].1.as_f64().unwrap();
                (x - 0.25).abs() < 0.25 && (y - 0.75).abs() < 0.25
            })
            .count();
        // Uniform baseline would be 25%.
        assert!(close > n / 2, "cmaes focus: {close}/{n}");
    }

    #[test]
    fn sigma_decays_but_floors() {
        let c = CmaEsSampler::from_config(&AlgoConfig::new("cmaes"));
        let gens = 100;
        let sigma = (c.sigma0 * c.sigma_decay.powi(gens)).max(c.sigma_min);
        assert_eq!(sigma, c.sigma_min);
    }

    #[test]
    fn domain_respected() {
        let s = Space::from_json(
            &parse(
                r#"{"lr": {"low": 1e-5, "high": 1e-1, "type": "loguniform"},
                    "k": {"low": 1, "high": 4, "type": "int"},
                    "c": ["p", "q"]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let c = CmaEsSampler::from_config(&AlgoConfig::new("cmaes"));
        crate::testutil::prop::check(40, |g| {
            let n = g.usize(0, 50);
            let obs: Vec<Obs> = (0..n)
                .map(|_| Obs { params: s.sample(g.rng()), value: g.f64(-1.0, 1.0) })
                .collect();
            let a = c.suggest(&s, &obs, Direction::Maximize, n as u64, g.rng());
            for (name, v) in &a {
                if !s.contains(name, v) {
                    return Err(format!("{name}={v}"));
                }
            }
            Ok(())
        });
    }
}

//! Hyperparameter samplers.
//!
//! The paper's backend delegates suggestion to Optuna; here each
//! algorithm is implemented from scratch:
//!
//! | name          | algorithm |
//! |---------------|-----------|
//! | `random`      | independent uniform draws |
//! | `grid`        | mixed-radix grid walk (continuous dims discretized) |
//! | `qmc` / `sobol` | scrambled Halton low-discrepancy sequence |
//! | `tpe`         | Tree-structured Parzen Estimator, reproducing Optuna's defaults ([`tpe`]) |
//! | `gp`          | Gaussian-process Bayesian optimization with expected improvement ([`gp`]) |
//! | `cmaes`       | separable CMA-ES-style evolutionary sampler ([`cmaes`]) |
//!
//! Samplers are deterministic functions of `(study history, rng)` so a
//! server restart (history replayed from the WAL) reproduces the same
//! suggestion stream.

pub mod cmaes;
pub mod gp;
pub mod nsga2;
pub mod tpe;

use super::space::{Assignment, Direction, Space};
use super::study::AlgoConfig;
use crate::rng::Rng;

/// One finished observation shown to a sampler: the assignment and its
/// objective value (completed trials at their final value, pruned trials
/// at their last intermediate — see `Study::scored`).
#[derive(Clone, Debug)]
pub struct Obs {
    pub params: Assignment,
    pub value: f64,
}

/// Opaque fitted-model state produced by [`Sampler::fit`] and consumed by
/// [`Sampler::suggest_fitted`]. The engine caches one per study keyed by
/// the tell-epoch, so the concrete type must be shareable across asks
/// (`Send + Sync`) and downcastable by its own sampler (`as_any`).
pub trait FitState: Send + Sync {
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Trivial fit for samplers that never read the history.
pub struct NoFit;

impl FitState for NoFit {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Sampler interface. `n_started` counts all asks so far in the study
/// (running included) — sequence-based samplers (grid/qmc) key on it.
///
/// The interface is split into a *fit* phase (pure function of the
/// history, no RNG) and a *draw* phase (consumes the per-trial RNG).
/// `suggest` is the provided composition of the two, which guarantees
/// that a cached fit reused across asks produces byte-identical
/// suggestions to a cold fit-per-ask: both paths run the exact same
/// code, the cache only skips recomputing an identical `FitState`.
pub trait Sampler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether `fit` reads the observation history. When false the engine
    /// skips building the history snapshot entirely (random/grid/qmc).
    fn needs_history(&self) -> bool {
        true
    }

    /// Digest the history into sufficient statistics for drawing. Must
    /// not consume RNG — determinism of the suggestion stream relies on
    /// the draw phase being the only RNG consumer.
    fn fit(&self, space: &Space, obs: &[Obs], direction: Direction) -> Box<dyn FitState>;

    /// Draw one suggestion from a fitted state. Implementations fall back
    /// to `space.sample(rng)` if handed a foreign `FitState` type.
    fn suggest_fitted(
        &self,
        space: &Space,
        fit: &dyn FitState,
        n_started: u64,
        rng: &mut Rng,
    ) -> Assignment;

    fn suggest(
        &self,
        space: &Space,
        obs: &[Obs],
        direction: Direction,
        n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        self.suggest_fitted(space, self.fit(space, obs, direction).as_ref(), n_started, rng)
    }
}

/// Whether `name` is a sampler [`make_sampler`] can instantiate. Lets the
/// engine reject bad names *before* any side effects (study creation is
/// persisted ahead of sampler construction).
pub fn is_known_sampler(name: &str) -> bool {
    matches!(
        name,
        "random" | "grid" | "qmc" | "sobol" | "tpe" | "gp" | "cmaes"
    )
}

/// Instantiate a sampler from its study configuration.
pub fn make_sampler(cfg: &AlgoConfig) -> Result<Box<dyn Sampler>, String> {
    match cfg.name.as_str() {
        "random" => Ok(Box::new(RandomSampler)),
        "grid" => Ok(Box::new(GridSampler {
            grid_points: cfg.u64_opt("grid_points", 10).max(2) as usize,
        })),
        "qmc" | "sobol" => Ok(Box::new(QmcSampler)),
        "tpe" => Ok(Box::new(tpe::TpeSampler::from_config(cfg))),
        "gp" => Ok(Box::new(gp::GpSampler::from_config(cfg))),
        "cmaes" => Ok(Box::new(cmaes::CmaEsSampler::from_config(cfg))),
        other => Err(format!("unknown sampler '{other}'")),
    }
}

/// Independent uniform sampling — the baseline of every HPO comparison.
pub struct RandomSampler;

impl Sampler for RandomSampler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn needs_history(&self) -> bool {
        false
    }

    fn fit(&self, _space: &Space, _obs: &[Obs], _direction: Direction) -> Box<dyn FitState> {
        Box::new(NoFit)
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        _fit: &dyn FitState,
        _n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        space.sample(rng)
    }
}

/// Exhaustive grid walk. Discrete dims enumerate their domain; continuous
/// dims are discretized to `grid_points` levels. The n-th ask visits the
/// n-th cell in mixed-radix order, wrapping around when the grid is
/// exhausted.
pub struct GridSampler {
    pub grid_points: usize,
}

impl GridSampler {
    fn radices(&self, space: &Space) -> Vec<usize> {
        space
            .params
            .iter()
            .map(|p| match &p.dist {
                super::space::Dist::Cat { choices } => choices.len(),
                super::space::Dist::Int { low, high } => {
                    ((high - low + 1) as usize).min(self.grid_points)
                }
                _ => self.grid_points,
            })
            .collect()
    }
}

impl Sampler for GridSampler {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn needs_history(&self) -> bool {
        false
    }

    fn fit(&self, _space: &Space, _obs: &[Obs], _direction: Direction) -> Box<dyn FitState> {
        Box::new(NoFit)
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        _fit: &dyn FitState,
        n_started: u64,
        _rng: &mut Rng,
    ) -> Assignment {
        let radices = self.radices(space);
        let total: u64 = radices.iter().map(|&r| r as u64).product();
        let mut idx = n_started % total.max(1);
        let mut unit = Vec::with_capacity(radices.len());
        for &r in &radices {
            let digit = (idx % r as u64) as f64;
            idx /= r as u64;
            // Cell centers.
            unit.push((digit + 0.5) / r as f64);
        }
        space.from_unit(&unit)
    }
}

/// Low-discrepancy sampler: Halton sequence with per-study digit
/// scrambling (deterministic in the trial index). Registered under both
/// `qmc` and `sobol` — see DESIGN.md §3 substitutions.
pub struct QmcSampler;

const PRIMES: [u64; 16] = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

fn halton(index: u64, base: u64, scramble: u64) -> f64 {
    let mut f = 1.0;
    let mut r = 0.0;
    let mut i = index + 1; // skip the origin
    let mut digit_pos = 0u64;
    while i > 0 {
        f /= base as f64;
        let digit = i % base;
        // Deterministic digit permutation per (base, position).
        let perm = (digit + scramble.wrapping_mul(digit_pos + 1)) % base;
        r += f * perm as f64;
        i /= base;
        digit_pos += 1;
    }
    r
}

impl Sampler for QmcSampler {
    fn name(&self) -> &'static str {
        "qmc"
    }

    fn needs_history(&self) -> bool {
        false
    }

    fn fit(&self, _space: &Space, _obs: &[Obs], _direction: Direction) -> Box<dyn FitState> {
        Box::new(NoFit)
    }

    fn suggest_fitted(
        &self,
        space: &Space,
        _fit: &dyn FitState,
        n_started: u64,
        rng: &mut Rng,
    ) -> Assignment {
        // Scramble derived from the rng stream head so distinct studies
        // decorrelate, but the sequence itself is indexed by trial count.
        let scramble = rng.next_u64() % 1000;
        let unit: Vec<f64> = (0..space.len())
            .map(|d| halton(n_started, PRIMES[d % PRIMES.len()], scramble + d as u64))
            .collect();
        space.from_unit(&unit)
    }
}

/// Helper shared by model-based samplers: observations as unit-cube rows
/// with values oriented for minimization.
pub(crate) fn unit_history(
    space: &Space,
    obs: &[Obs],
    direction: Direction,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut xs = Vec::with_capacity(obs.len());
    let mut ys = Vec::with_capacity(obs.len());
    for o in obs {
        if let Some(u) = space.to_unit(&o.params) {
            if o.value.is_finite() {
                xs.push(u);
                ys.push(match direction {
                    Direction::Minimize => o.value,
                    Direction::Maximize => -o.value,
                });
            }
        }
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn space() -> Space {
        Space::from_json(
            &parse(
                r#"{
                "x": {"low": 0.0, "high": 1.0},
                "n": {"low": 1, "high": 3, "type": "int"},
                "c": ["a", "b"]
            }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn random_in_domain() {
        let s = space();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let a = RandomSampler.suggest(&s, &[], Direction::Minimize, 0, &mut rng);
            for (n, v) in &a {
                assert!(s.contains(n, v), "{n}={v}");
            }
        }
    }

    #[test]
    fn grid_covers_all_cells() {
        let s = Space::from_json(
            &parse(r#"{"n": {"low": 1, "high": 3, "type": "int"}, "c": ["a", "b"]}"#).unwrap(),
        )
        .unwrap();
        let g = GridSampler { grid_points: 10 };
        let mut rng = Rng::new(1);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..6 {
            let a = g.suggest(&s, &[], Direction::Minimize, i, &mut rng);
            seen.insert(format!("{:?}", a));
        }
        assert_eq!(seen.len(), 6, "3 ints × 2 cats = 6 distinct cells");
        // Wraps around after exhaustion.
        let a0 = g.suggest(&s, &[], Direction::Minimize, 0, &mut rng);
        let a6 = g.suggest(&s, &[], Direction::Minimize, 6, &mut rng);
        assert_eq!(format!("{a0:?}"), format!("{a6:?}"));
    }

    #[test]
    fn qmc_low_discrepancy_vs_random_1d() {
        // Star discrepancy proxy: max gap between sorted samples in 1-D
        // should be smaller for Halton than the expected max gap of
        // uniform random.
        let s = Space::from_json(&parse(r#"{"x": {"low": 0.0, "high": 1.0}}"#).unwrap()).unwrap();
        let q = QmcSampler;
        let mut rng = Rng::new(7);
        let n = 64;
        let mut xs: Vec<f64> = (0..n)
            .map(|i| {
                let mut r2 = Rng::new(7); // same scramble each call
                q.suggest(&s, &[], Direction::Minimize, i, &mut r2)[0]
                    .1
                    .as_f64()
                    .unwrap()
            })
            .collect();
        xs.sort_by(f64::total_cmp);
        let max_gap = xs.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max);
        assert!(max_gap < 0.08, "halton max gap {max_gap}");
        let mut rs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        rs.sort_by(f64::total_cmp);
        let rand_gap = rs.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max);
        assert!(max_gap < rand_gap, "halton {max_gap} vs random {rand_gap}");
    }

    #[test]
    fn factory_dispatch() {
        for name in ["random", "grid", "qmc", "sobol", "tpe", "gp", "cmaes"] {
            assert!(make_sampler(&AlgoConfig::new(name)).is_ok(), "{name}");
        }
        assert!(make_sampler(&AlgoConfig::new("nope")).is_err());
    }

    #[test]
    fn needs_history_flags() {
        for (name, expect) in [
            ("random", false),
            ("grid", false),
            ("qmc", false),
            ("sobol", false),
            ("tpe", true),
            ("gp", true),
            ("cmaes", true),
        ] {
            let s = make_sampler(&AlgoConfig::new(name)).unwrap();
            assert_eq!(s.needs_history(), expect, "{name}");
        }
    }

    #[test]
    fn is_known_sampler_matches_factory() {
        for name in ["random", "grid", "qmc", "sobol", "tpe", "gp", "cmaes", "nope", ""] {
            assert_eq!(
                is_known_sampler(name),
                make_sampler(&AlgoConfig::new(name)).is_ok(),
                "{name}"
            );
        }
    }

    #[test]
    fn suggest_equals_fit_then_draw() {
        // The provided `suggest` must be exactly fit → suggest_fitted for
        // every sampler: this is the determinism argument for the fit
        // cache (same epoch → same FitState → same draw).
        let s = space();
        let mut rng = Rng::new(41);
        let obs: Vec<Obs> = (0..30)
            .map(|i| Obs { params: s.sample(&mut rng), value: (i as f64 * 0.37).sin() })
            .collect();
        for name in ["random", "grid", "qmc", "tpe", "gp", "cmaes"] {
            let smp = make_sampler(&AlgoConfig::new(name)).unwrap();
            let fit = smp.fit(&s, &obs, Direction::Minimize);
            for n_started in [0u64, 7, 31] {
                let mut r1 = Rng::new(1000 + n_started);
                let mut r2 = r1.clone();
                let a = smp.suggest(&s, &obs, Direction::Minimize, n_started, &mut r1);
                let b = smp.suggest_fitted(&s, fit.as_ref(), n_started, &mut r2);
                assert_eq!(
                    format!("{a:?}"),
                    format!("{b:?}"),
                    "{name} n_started={n_started}"
                );
            }
        }
    }

    #[test]
    fn foreign_fit_state_falls_back_to_uniform() {
        let s = space();
        let tpe = make_sampler(&AlgoConfig::new("tpe")).unwrap();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = tpe.suggest_fitted(&s, &NoFit, 3, &mut r1);
        let b = s.sample(&mut r2);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn unit_history_orients_for_minimize() {
        let s = space();
        let mut rng = Rng::new(3);
        let a = s.sample(&mut rng);
        let obs = vec![Obs { params: a, value: 2.0 }];
        let (_, ys_min) = unit_history(&s, &obs, Direction::Minimize);
        let (_, ys_max) = unit_history(&s, &obs, Direction::Maximize);
        assert_eq!(ys_min[0], 2.0);
        assert_eq!(ys_max[0], -2.0);
    }

    #[test]
    fn unit_history_skips_nonfinite() {
        let s = space();
        let mut rng = Rng::new(3);
        let obs = vec![
            Obs { params: s.sample(&mut rng), value: f64::NAN },
            Obs { params: s.sample(&mut rng), value: 1.0 },
        ];
        let (xs, ys) = unit_history(&s, &obs, Direction::Minimize);
        assert_eq!(xs.len(), 1);
        assert_eq!(ys, vec![1.0]);
    }
}

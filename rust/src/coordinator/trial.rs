//! Trial state machine.
//!
//! A *trial* is a single training attempt with a specific hyperparameter
//! assignment (paper §2). The server creates it on `ask`, receives
//! intermediate `(step, value)` reports via `should_prune`, and finalizes
//! it via `tell` — or marks it pruned/failed. Terminal states are
//! absorbing: a `tell` for a pruned trial is a client error, not a state
//! change.

use super::space::{assignment_to_json, Assignment};
use crate::json::Value;

/// Trial lifecycle states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialState {
    /// Hyperparameters handed out; awaiting reports.
    Running,
    /// Finalized with an objective value via `tell`.
    Completed,
    /// Aborted by the pruner (client confirmed via prune response).
    Pruned,
    /// Reported failed by the client, or reaped by the server after its
    /// node went silent (opportunistic resources disappear).
    Failed,
}

impl TrialState {
    pub fn as_str(&self) -> &'static str {
        match self {
            TrialState::Running => "running",
            TrialState::Completed => "completed",
            TrialState::Pruned => "pruned",
            TrialState::Failed => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        !matches!(self, TrialState::Running)
    }
}

/// Error for invalid state transitions (mapped to HTTP 409 upstream).
#[derive(Debug, thiserror::Error, PartialEq)]
#[error("trial {id} is {state:?}: {action} not allowed")]
pub struct StateError {
    pub id: u64,
    pub state: TrialState,
    pub action: &'static str,
}

/// A single trial.
#[derive(Clone, Debug)]
pub struct Trial {
    /// Server-wide unique id (the paper's "unique identifier" returned by
    /// `ask` and echoed by `tell`/`should_prune`).
    pub id: u64,
    /// Index within its study (0-based, creation order).
    pub number: u64,
    pub state: TrialState,
    pub params: Assignment,
    /// Final objective value (set on completion; single-objective).
    pub value: Option<f64>,
    /// Final objective vector (multi-objective studies).
    pub values: Option<Vec<f64>>,
    /// Intermediate reports, strictly ordered by step.
    pub intermediate: Vec<(u64, f64)>,
    /// Wall-clock bookkeeping (seconds since server start).
    pub started_at: f64,
    pub finished_at: Option<f64>,
    /// Client-supplied node label (site attribution in the dashboard).
    pub node: Option<String>,
}

impl Trial {
    pub fn new(id: u64, number: u64, params: Assignment, now: f64, node: Option<String>) -> Trial {
        Trial {
            id,
            number,
            state: TrialState::Running,
            params,
            value: None,
            values: None,
            intermediate: Vec::new(),
            started_at: now,
            finished_at: None,
            node,
        }
    }

    fn ensure_running(&self, action: &'static str) -> Result<(), StateError> {
        if self.state != TrialState::Running {
            return Err(StateError { id: self.id, state: self.state, action });
        }
        Ok(())
    }

    /// Would a terminal transition (`tell`/`prune`/`fail`) be accepted
    /// right now? The engine persists the WAL record between this check
    /// and the apply, so the two must agree — which they do by
    /// construction: every transition's only precondition is
    /// `ensure_running`.
    pub fn validate_transition(&self, action: &'static str) -> Result<(), StateError> {
        self.ensure_running(action)
    }

    /// Would `report(step, _)` be accepted? Running and non-regressing.
    /// [`Trial::report`] calls this itself, so engine-side
    /// validate-persist-apply cannot drift from the state machine.
    pub fn validate_report(&self, step: u64) -> Result<(), StateError> {
        self.ensure_running("should_prune")?;
        if let Some(&(last, _)) = self.intermediate.last() {
            if step < last {
                return Err(StateError {
                    id: self.id,
                    state: self.state,
                    action: "report-regress",
                });
            }
        }
        Ok(())
    }

    /// Finalize with an objective value (`tell`).
    pub fn complete(&mut self, value: f64, now: f64) -> Result<(), StateError> {
        self.ensure_running("tell")?;
        self.state = TrialState::Completed;
        self.value = Some(value);
        self.finished_at = Some(now);
        Ok(())
    }

    /// Finalize a multi-objective trial (`tell` with `values`).
    pub fn complete_mo(&mut self, values: Vec<f64>, now: f64) -> Result<(), StateError> {
        self.ensure_running("tell")?;
        self.state = TrialState::Completed;
        self.values = Some(values);
        self.finished_at = Some(now);
        Ok(())
    }

    /// Record an intermediate report (`should_prune`). Steps must be
    /// non-decreasing; an equal step overwrites (client retry).
    pub fn report(&mut self, step: u64, value: f64) -> Result<(), StateError> {
        self.validate_report(step)?;
        if let Some(&(last, _)) = self.intermediate.last() {
            if step == last {
                self.intermediate.pop();
            }
        }
        self.intermediate.push((step, value));
        Ok(())
    }

    /// Mark pruned.
    pub fn prune(&mut self, now: f64) -> Result<(), StateError> {
        self.ensure_running("prune")?;
        self.state = TrialState::Pruned;
        self.finished_at = Some(now);
        Ok(())
    }

    /// Mark failed.
    pub fn fail(&mut self, now: f64) -> Result<(), StateError> {
        self.ensure_running("fail")?;
        self.state = TrialState::Failed;
        self.finished_at = Some(now);
        Ok(())
    }

    /// Last intermediate value, if any.
    pub fn last_intermediate(&self) -> Option<(u64, f64)> {
        self.intermediate.last().copied()
    }

    /// Intermediate value at an exact step.
    pub fn intermediate_at(&self, step: u64) -> Option<f64> {
        self.intermediate
            .iter()
            .find(|(s, _)| *s == step)
            .map(|(_, v)| *v)
    }

    /// JSON for dashboards / persistence.
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", self.id)
            .set("number", self.number)
            .set("state", self.state.as_str())
            .set("params", assignment_to_json(&self.params))
            .set("value", self.value)
            .set(
                "values",
                self.values
                    .as_ref()
                    .map(|vs| Value::Arr(vs.iter().map(|&v| Value::Num(v)).collect()))
                    .unwrap_or(Value::Null),
            )
            .set(
                "intermediate",
                Value::Arr(
                    self.intermediate
                        .iter()
                        .map(|(s, v)| Value::Arr(vec![Value::Num(*s as f64), Value::Num(*v)]))
                        .collect(),
                ),
            )
            .set("started_at", self.started_at)
            .set("finished_at", self.finished_at)
            .set("node", self.node.clone().map(Value::Str).unwrap_or(Value::Null));
        Value::Obj(o)
    }

    /// Rebuild from the JSON produced by [`Trial::to_json`] (recovery).
    pub fn from_json(v: &Value) -> Option<Trial> {
        let state = match v.get("state").as_str()? {
            "running" => TrialState::Running,
            "completed" => TrialState::Completed,
            "pruned" => TrialState::Pruned,
            "failed" => TrialState::Failed,
            _ => return None,
        };
        let params: Assignment = v
            .get("params")
            .as_obj()?
            .iter()
            .map(|(k, val)| (k.to_string(), val.clone()))
            .collect();
        let intermediate = v
            .get("intermediate")
            .as_arr()?
            .iter()
            .filter_map(|p| Some((p.at(0).as_u64()?, p.at(1).as_f64()?)))
            .collect();
        Some(Trial {
            id: v.get("id").as_u64()?,
            number: v.get("number").as_u64()?,
            state,
            params,
            value: v.get("value").as_f64(),
            values: v
                .get("values")
                .as_arr()
                .map(|a| a.iter().filter_map(Value::as_f64).collect()),
            intermediate,
            started_at: v.get("started_at").as_f64().unwrap_or(0.0),
            finished_at: v.get("finished_at").as_f64(),
            node: v.get("node").as_str().map(|s| s.to_string()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    fn trial() -> Trial {
        Trial::new(7, 0, vec![("x".into(), Value::Num(1.5))], 10.0, Some("n1".into()))
    }

    #[test]
    fn validators_agree_with_transitions() {
        // The engine persists a WAL record between validate and apply;
        // these assertions pin the two to the same predicates.
        let mut t = trial();
        assert!(t.validate_transition("tell").is_ok());
        assert!(t.validate_report(1).is_ok());
        t.report(3, 1.0).unwrap();
        assert!(t.validate_report(2).is_err(), "regressing step rejected");
        assert!(t.validate_report(3).is_ok(), "equal step (retry) accepted");
        t.complete(1.0, 1.0).unwrap();
        assert!(t.validate_transition("tell").is_err());
        assert!(t.validate_report(4).is_err());
    }

    #[test]
    fn lifecycle_complete() {
        let mut t = trial();
        assert_eq!(t.state, TrialState::Running);
        t.report(1, 0.9).unwrap();
        t.report(2, 0.5).unwrap();
        t.complete(0.4, 20.0).unwrap();
        assert_eq!(t.state, TrialState::Completed);
        assert_eq!(t.value, Some(0.4));
        assert_eq!(t.finished_at, Some(20.0));
    }

    #[test]
    fn terminal_states_absorbing() {
        let mut t = trial();
        t.prune(11.0).unwrap();
        assert!(t.complete(1.0, 12.0).is_err());
        assert!(t.report(3, 1.0).is_err());
        assert!(t.fail(12.0).is_err());
        assert_eq!(t.state, TrialState::Pruned);
    }

    #[test]
    fn report_step_monotonic() {
        let mut t = trial();
        t.report(5, 1.0).unwrap();
        assert!(t.report(3, 0.9).is_err());
        // Same step overwrites (idempotent client retry).
        t.report(5, 0.8).unwrap();
        assert_eq!(t.intermediate, vec![(5, 0.8)]);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = trial();
        t.report(1, 2.0).unwrap();
        t.complete(1.5, 30.0).unwrap();
        let j = t.to_json();
        let back = Trial::from_json(&j).unwrap();
        assert_eq!(back.id, t.id);
        assert_eq!(back.state, t.state);
        assert_eq!(back.value, t.value);
        assert_eq!(back.intermediate, t.intermediate);
        assert_eq!(back.params.len(), 1);
        assert_eq!(back.node.as_deref(), Some("n1"));
    }

    #[test]
    fn prop_state_machine_no_terminal_escape() {
        // Random action sequences never escape a terminal state and
        // `value` is set iff completed.
        prop::check(200, |g| {
            let mut t = trial();
            let mut step = 0u64;
            for _ in 0..g.usize(1, 20) {
                match g.rng().below(4) {
                    0 => {
                        step += 1;
                        let _ = t.report(step, g.f64(-1.0, 1.0));
                    }
                    1 => {
                        let _ = t.complete(g.f64(-1.0, 1.0), 1.0);
                    }
                    2 => {
                        let _ = t.prune(1.0);
                    }
                    _ => {
                        let _ = t.fail(1.0);
                    }
                }
                let value_ok = (t.value.is_some()) == (t.state == TrialState::Completed);
                if !value_ok {
                    return Err(format!("value/state mismatch: {:?}", t.state));
                }
                if t.state.is_terminal() && t.finished_at.is_none() {
                    return Err("terminal without finished_at".into());
                }
            }
            Ok(())
        });
    }
}

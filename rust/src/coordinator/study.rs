//! Study model and canonical study identity.
//!
//! A *study* is an optimization session: a search space, a direction, a
//! sampler and an optional pruner, plus the collection of trials run so
//! far (paper §2). HOPAAS has no study-registration API — the `ask` body
//! carries the whole definition, and the server routes the request to the
//! study with the same *canonical key* (or creates it). The key is the
//! SHA-256 of the canonical JSON of every field that defines the study
//! unambiguously: name, search space, direction, sampler and pruner
//! configuration.

use super::samplers::{FitState, Obs, Sampler};
use super::space::{Direction, Space};
use super::trial::{Trial, TrialState};
use crate::json::Value;
use sha2::{Digest, Sha256};
use std::sync::Arc;

/// Sampler/pruner configuration: algorithm name + free-form options.
#[derive(Clone, Debug, PartialEq)]
pub struct AlgoConfig {
    pub name: String,
    pub options: Value,
}

impl AlgoConfig {
    pub fn new(name: &str) -> AlgoConfig {
        AlgoConfig { name: name.to_string(), options: Value::Obj(crate::json::Value::obj()) }
    }

    /// Parse from either `"tpe"` or `{"name": "tpe", ...opts}`.
    pub fn from_json(v: &Value, default_name: &str) -> AlgoConfig {
        match v {
            Value::Str(s) => AlgoConfig::new(s),
            Value::Obj(o) => {
                let name = o
                    .get("name")
                    .and_then(Value::as_str)
                    .unwrap_or(default_name)
                    .to_string();
                let mut options = crate::json::Value::obj();
                for (k, val) in o.iter() {
                    if k != "name" {
                        options.set(k, val.clone());
                    }
                }
                AlgoConfig { name, options: Value::Obj(options) }
            }
            _ => AlgoConfig::new(default_name),
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = crate::json::Value::obj();
        o.set("name", self.name.as_str());
        if let Some(opts) = self.options.as_obj() {
            for (k, v) in opts.iter() {
                o.set(k, v.clone());
            }
        }
        Value::Obj(o)
    }

    /// Numeric option accessor.
    pub fn f64_opt(&self, key: &str, default: f64) -> f64 {
        self.options.get(key).as_f64().unwrap_or(default)
    }

    pub fn u64_opt(&self, key: &str, default: u64) -> u64 {
        self.options.get(key).as_u64().unwrap_or(default)
    }
}

/// Immutable study definition (what the canonical key hashes).
#[derive(Clone, Debug, PartialEq)]
pub struct StudyDef {
    pub name: String,
    pub space: Space,
    pub direction: Direction,
    /// Multi-objective studies (paper §5 future work): per-objective
    /// directions. `None` = classic single-objective (`direction`).
    pub directions: Option<Vec<Direction>>,
    pub sampler: AlgoConfig,
    pub pruner: Option<AlgoConfig>,
}

impl StudyDef {
    /// Is this a multi-objective study?
    pub fn is_mo(&self) -> bool {
        self.directions.is_some()
    }
}

impl StudyDef {
    /// Canonical JSON — field order fixed, space in client key order.
    pub fn canonical_json(&self) -> Value {
        let mut o = crate::json::Value::obj();
        o.set("name", self.name.as_str())
            .set("properties", self.space.to_json())
            .set(
                "direction",
                match &self.directions {
                    None => Value::Str(self.direction.as_str().to_string()),
                    Some(ds) => Value::Arr(
                        ds.iter().map(|d| Value::Str(d.as_str().to_string())).collect(),
                    ),
                },
            )
            .set("sampler", self.sampler.to_json())
            .set(
                "pruner",
                self.pruner.as_ref().map(|p| p.to_json()).unwrap_or(Value::Null),
            );
        Value::Obj(o)
    }

    /// Canonical study key (hex SHA-256).
    pub fn key(&self) -> String {
        let mut h = Sha256::new();
        h.update(self.canonical_json().to_string().as_bytes());
        let digest = h.finalize();
        digest.iter().map(|b| format!("{b:02x}")).collect()
    }
}

/// Non-persisted per-study runtime caches for the ask hot path: the
/// sampler instance (built once per study slot), the tell-epoch, the
/// `Arc`-shared windowed observation snapshot, and the cached sampler
/// fit. None of this is serialized — recovery builds a fresh `Study`
/// (epoch 0, empty caches), so WAL replay invalidates everything by
/// construction and the first post-recovery ask rebuilds from `trials`.
#[derive(Default)]
pub struct StudyRuntime {
    /// Tell-epoch: bumped whenever `scored()` content changes (a tell or
    /// a prune-with-intermediate). Keys both caches below.
    pub epoch: u64,
    /// Sampler constructed once per study and reused across asks.
    pub sampler: Option<Arc<dyn Sampler>>,
    /// Cached fit and the epoch it was built from; valid while the epoch
    /// still matches [`StudyRuntime::epoch`].
    pub fit: Option<(u64, Arc<dyn FitState>)>,
    obs: Option<ObsSnap>,
}

/// Windowed scored-observation snapshot in trial-insert order (exactly
/// the `scored()` + skip semantics the ask path historically used).
struct ObsSnap {
    epoch: u64,
    /// Index into `trials` of the last observation included, or -1.
    last_idx: i64,
    window: Arc<Vec<Obs>>,
}

/// A study and its trials.
pub struct Study {
    /// Short server-assigned id (ordinal), used in URLs.
    pub id: u64,
    pub def: StudyDef,
    pub key: String,
    pub trials: Vec<Trial>,
    pub created_at: f64,
    /// Runtime-only caches (never persisted or compared).
    pub runtime: StudyRuntime,
    /// Next trial number to hand out. Reserved under the shard lock
    /// *before* sampling (see `Engine::ask`), so concurrent asks on the
    /// same study draw distinct numbers — and therefore distinct,
    /// deterministic suggestion seeds — instead of racing to the same
    /// `trials.len()`. May run ahead of `trials.len()` while a reserved
    /// ask is still sampling outside the lock.
    next_number: u64,
}

impl Study {
    pub fn new(id: u64, def: StudyDef, now: f64) -> Study {
        let key = def.key();
        Study {
            id,
            def,
            key,
            trials: Vec::new(),
            created_at: now,
            runtime: StudyRuntime::default(),
            next_number: 0,
        }
    }

    /// Reserve the next trial number (call with the shard lock held).
    pub fn reserve_number(&mut self) -> u64 {
        let n = self.next_number;
        self.next_number += 1;
        n
    }

    /// Note a trial number seen during recovery, keeping the reservation
    /// counter ahead of every recovered trial.
    pub fn note_trial_number(&mut self, number: u64) {
        self.next_number = self.next_number.max(number + 1);
    }

    /// Completed trials (have a final value).
    pub fn completed(&self) -> impl Iterator<Item = &Trial> {
        self.trials
            .iter()
            .filter(|t| t.state == TrialState::Completed)
    }

    /// Trials that terminated with a usable objective estimate:
    /// completed trials at their final value, pruned trials at their last
    /// intermediate (Optuna's TPE does the same, so pruned trials still
    /// inform the surrogate).
    pub fn scored(&self) -> Vec<(&Trial, f64)> {
        self.trials
            .iter()
            .filter_map(|t| match t.state {
                TrialState::Completed => Some((t, t.value.unwrap())),
                TrialState::Pruned => t.last_intermediate().map(|(_, v)| (t, v)),
                _ => None,
            })
            .collect()
    }

    /// `Arc`-shared windowed observation snapshot: the most recent `cap`
    /// entries of `scored()` in trial-insert order. Returns the cached
    /// copy (a cheap `Arc` clone, zero per-trial work) while the epoch is
    /// unchanged; rebuilds lazily otherwise.
    pub fn obs_window(&mut self, cap: usize) -> Arc<Vec<Obs>> {
        let epoch = self.runtime.epoch;
        if let Some(snap) = &self.runtime.obs {
            if snap.epoch == epoch {
                return snap.window.clone();
            }
        }
        let mut scored: Vec<(usize, f64)> = Vec::new();
        for (i, t) in self.trials.iter().enumerate() {
            match t.state {
                TrialState::Completed => scored.push((i, t.value.unwrap())),
                TrialState::Pruned => {
                    if let Some((_, v)) = t.last_intermediate() {
                        scored.push((i, v));
                    }
                }
                _ => {}
            }
        }
        let skip = scored.len().saturating_sub(cap.max(1));
        let last_idx = scored.last().map_or(-1, |&(i, _)| i as i64);
        let window: Vec<Obs> = scored[skip..]
            .iter()
            .map(|&(i, v)| Obs { params: self.trials[i].params.clone(), value: v })
            .collect();
        let window = Arc::new(window);
        self.runtime.obs = Some(ObsSnap { epoch, last_idx, window: window.clone() });
        window
    }

    /// Record that `trials[trial_idx]` just gained a score (tell or
    /// prune-with-intermediate): bumps the tell-epoch, and extends the
    /// cached window in place when the score arrived in insert order (the
    /// common case — append via `Arc::make_mut` is copy-on-write only if
    /// an in-flight ask still holds the snapshot). Out-of-order scores
    /// drop the snapshot for a lazy rebuild on the next ask.
    pub fn note_scored(&mut self, trial_idx: usize, cap: usize) {
        self.runtime.epoch += 1;
        if self.runtime.obs.is_none() {
            return;
        }
        let in_order = self
            .runtime
            .obs
            .as_ref()
            .is_some_and(|s| (trial_idx as i64) > s.last_idx);
        let obs = {
            let t = &self.trials[trial_idx];
            let value = match t.state {
                TrialState::Completed => t.value,
                TrialState::Pruned => t.last_intermediate().map(|(_, v)| v),
                _ => None,
            };
            value.map(|v| Obs { params: t.params.clone(), value: v })
        };
        match (in_order, obs) {
            (true, Some(obs)) => {
                let snap = self.runtime.obs.as_mut().unwrap();
                let w = Arc::make_mut(&mut snap.window);
                w.push(obs);
                let cap = cap.max(1);
                if w.len() > cap {
                    let excess = w.len() - cap;
                    w.drain(..excess);
                }
                snap.last_idx = trial_idx as i64;
                snap.epoch = self.runtime.epoch;
            }
            _ => self.runtime.obs = None,
        }
    }

    /// Number of trials in a given state.
    pub fn count(&self, state: TrialState) -> usize {
        self.trials.iter().filter(|t| t.state == state).count()
    }

    /// Completed multi-objective trials with their objective vectors.
    pub fn mo_scored(&self) -> Vec<(&Trial, &Vec<f64>)> {
        self.trials
            .iter()
            .filter(|t| t.state == TrialState::Completed)
            .filter_map(|t| t.values.as_ref().map(|v| (t, v)))
            .collect()
    }

    /// Pareto-optimal completed trials of a multi-objective study.
    pub fn pareto(&self) -> Vec<&Trial> {
        let Some(directions) = &self.def.directions else { return Vec::new() };
        let scored = self.mo_scored();
        let oriented: Vec<Vec<f64>> = scored
            .iter()
            .filter(|(_, v)| v.len() == directions.len())
            .map(|(_, v)| super::mo::orient(v, directions))
            .collect();
        let usable: Vec<&Trial> = scored
            .iter()
            .filter(|(_, v)| v.len() == directions.len())
            .map(|(t, _)| *t)
            .collect();
        super::mo::pareto_front(&oriented)
            .into_iter()
            .map(|i| usable[i])
            .collect()
    }

    /// Best completed trial under the study direction (single-objective
    /// trials only — multi-objective trials carry `values`, not `value`,
    /// and are ranked by Pareto dominance instead; see [`Study::pareto`]).
    pub fn best(&self) -> Option<&Trial> {
        self.completed()
            .filter(|t| t.value.is_some())
            .reduce(|best, t| {
                if self
                    .def
                    .direction
                    .better(t.value.unwrap(), best.value.unwrap())
                {
                    t
                } else {
                    best
                }
            })
    }

    /// Dashboard summary JSON.
    pub fn summary_json(&self) -> Value {
        let mut o = crate::json::Value::obj();
        o.set("id", self.id)
            .set("key", self.key.as_str())
            .set("name", self.def.name.as_str())
            .set("direction", self.def.direction.as_str())
            .set("sampler", self.def.sampler.to_json())
            .set(
                "pruner",
                self.def.pruner.as_ref().map(|p| p.to_json()).unwrap_or(Value::Null),
            )
            .set("properties", self.def.space.to_json())
            .set("n_trials", self.trials.len())
            .set("n_running", self.count(TrialState::Running))
            .set("n_completed", self.count(TrialState::Completed))
            .set("n_pruned", self.count(TrialState::Pruned))
            .set("n_failed", self.count(TrialState::Failed))
            .set("created_at", self.created_at)
            .set(
                "best_value",
                self.best().and_then(|t| t.value).map(Value::Num).unwrap_or(Value::Null),
            )
            .set(
                "best_trial",
                self.best().map(|t| Value::Num(t.id as f64)).unwrap_or(Value::Null),
            );
        if let Some(ds) = &self.def.directions {
            o.set(
                "directions",
                Value::Arr(ds.iter().map(|d| Value::Str(d.as_str().into())).collect()),
            )
            .set("pareto_size", self.pareto().len());
        }
        Value::Obj(o)
    }
}

/// Parse a `StudyDef` from an `ask` request body.
///
/// Expected body shape (the HOPAAS Python client's convention):
/// ```json
/// {
///   "study_name": "GanPid-v1",
///   "properties": { ... search space ... },
///   "direction": "minimize",
///   "sampler": {"name": "tpe"},
///   "pruner": {"name": "median", "warmup_steps": 5},
///   "node": "marconi100-gpu-07"
/// }
/// ```
pub fn parse_ask_body(body: &Value) -> Result<(StudyDef, Option<String>), String> {
    let name = body
        .get("study_name")
        .as_str()
        .or_else(|| body.get("name").as_str())
        .unwrap_or("default")
        .to_string();
    let space = Space::from_json(body.get("properties")).map_err(|e| e.to_string())?;
    // "direction" is a string for single-objective studies or an array of
    // strings for multi-objective ones (paper §5 future work).
    let (direction, directions) = match body.get("direction") {
        Value::Null => (Direction::Minimize, None),
        Value::Arr(arr) => {
            if arr.len() < 2 {
                return Err("multi-objective 'direction' needs ≥ 2 entries".to_string());
            }
            let ds: Result<Vec<Direction>, String> = arr
                .iter()
                .map(|v| {
                    Direction::from_str(v.as_str().unwrap_or(""))
                        .ok_or_else(|| "direction entries must be 'minimize'/'maximize'".into())
                })
                .collect();
            let ds = ds?;
            (ds[0], Some(ds))
        }
        v => (
            Direction::from_str(v.as_str().unwrap_or(""))
                .ok_or_else(|| "direction must be 'minimize' or 'maximize'".to_string())?,
            None,
        ),
    };
    let sampler = match body.get("sampler") {
        Value::Null => AlgoConfig::new("tpe"),
        v => AlgoConfig::from_json(v, "tpe"),
    };
    let pruner = match body.get("pruner") {
        Value::Null => None,
        v => Some(AlgoConfig::from_json(v, "median")),
    };
    let node = body.get("node").as_str().map(|s| s.to_string());
    Ok((StudyDef { name, space, direction, directions, sampler, pruner }, node))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn def() -> StudyDef {
        let body = parse(
            r#"{
            "study_name": "s1",
            "properties": {"x": {"low": 0.0, "high": 1.0}},
            "direction": "minimize",
            "sampler": {"name": "tpe", "n_startup_trials": 5}
        }"#,
        )
        .unwrap();
        parse_ask_body(&body).unwrap().0
    }

    #[test]
    fn key_deterministic_and_sensitive() {
        let d1 = def();
        let d2 = def();
        assert_eq!(d1.key(), d2.key());
        let mut d3 = def();
        d3.name = "other".into();
        assert_ne!(d1.key(), d3.key());
        let mut d4 = def();
        d4.direction = Direction::Maximize;
        assert_ne!(d1.key(), d4.key());
        let mut d5 = def();
        d5.sampler = AlgoConfig::new("random");
        assert_ne!(d1.key(), d5.key());
    }

    #[test]
    fn key_is_hex_sha256() {
        let k = def().key();
        assert_eq!(k.len(), 64);
        assert!(k.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn parse_ask_defaults() {
        let body = parse(r#"{"properties": {"x": {"low": 0.0, "high": 1.0}}}"#).unwrap();
        let (d, node) = parse_ask_body(&body).unwrap();
        assert_eq!(d.name, "default");
        assert_eq!(d.direction, Direction::Minimize);
        assert_eq!(d.sampler.name, "tpe");
        assert!(d.pruner.is_none());
        assert!(node.is_none());
    }

    #[test]
    fn parse_ask_rejects_bad() {
        for bad in [
            r#"{}"#,
            r#"{"properties": {"x": {"low": 1, "high": 0}}}"#,
            r#"{"properties": {"x": {"low": 0, "high": 1}}, "direction": "sideways"}"#,
        ] {
            assert!(parse_ask_body(&parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn best_tracks_direction() {
        let mut s = Study::new(1, def(), 0.0);
        for (i, v) in [(0u64, 3.0), (1, 1.0), (2, 2.0)] {
            let mut t = Trial::new(i, i, vec![("x".into(), Value::Num(0.5))], 0.0, None);
            t.complete(v, 1.0).unwrap();
            s.trials.push(t);
        }
        assert_eq!(s.best().unwrap().id, 1);
        s.def.direction = Direction::Maximize;
        assert_eq!(s.best().unwrap().id, 0);
    }

    #[test]
    fn scored_includes_pruned_at_last_intermediate() {
        let mut s = Study::new(1, def(), 0.0);
        let mut t0 = Trial::new(0, 0, vec![("x".into(), Value::Num(0.5))], 0.0, None);
        t0.complete(1.0, 1.0).unwrap();
        let mut t1 = Trial::new(1, 1, vec![("x".into(), Value::Num(0.6))], 0.0, None);
        t1.report(3, 9.0).unwrap();
        t1.prune(1.0).unwrap();
        let t2 = Trial::new(2, 2, vec![("x".into(), Value::Num(0.7))], 0.0, None);
        s.trials.extend([t0, t1, t2]);
        let scored = s.scored();
        assert_eq!(scored.len(), 2);
        assert_eq!(scored[1].1, 9.0);
    }

    #[test]
    fn number_reservation_is_contiguous_and_recovery_aware() {
        let mut s = Study::new(1, def(), 0.0);
        assert_eq!(s.reserve_number(), 0);
        assert_eq!(s.reserve_number(), 1);
        // Recovery replays a trial with a higher number (e.g. a gap from
        // a failed persist): the counter stays ahead.
        s.note_trial_number(7);
        assert_eq!(s.reserve_number(), 8);
        s.note_trial_number(3); // lower numbers never move it back
        assert_eq!(s.reserve_number(), 9);
    }

    fn window_of(s: &Study, cap: usize) -> Vec<(String, f64)> {
        let all = s.scored();
        let skip = all.len().saturating_sub(cap.max(1));
        all.into_iter()
            .skip(skip)
            .map(|(t, v)| (format!("{:?}", t.params), v))
            .collect()
    }

    fn snap_of(s: &mut Study, cap: usize) -> Vec<(String, f64)> {
        s.obs_window(cap)
            .iter()
            .map(|o| (format!("{:?}", o.params), o.value))
            .collect()
    }

    #[test]
    fn obs_window_matches_scored_semantics() {
        let mut s = Study::new(1, def(), 0.0);
        for i in 0..10u64 {
            let mut t =
                Trial::new(i, i, vec![("x".into(), Value::Num(i as f64 / 10.0))], 0.0, None);
            if i % 3 == 0 {
                t.complete(i as f64, 1.0).unwrap();
            } else if i % 3 == 1 {
                t.report(1, i as f64 * 2.0).unwrap();
                t.prune(1.0).unwrap();
            }
            s.trials.push(t);
            if i % 3 != 2 {
                let idx = s.trials.len() - 1;
                s.note_scored(idx, 4);
            }
        }
        assert_eq!(snap_of(&mut s, 4), window_of(&s, 4), "capped");
        assert_eq!(snap_of(&mut s, 100), window_of(&s, 100), "uncapped");
    }

    #[test]
    fn note_scored_in_order_appends_without_rebuild() {
        let mut s = Study::new(1, def(), 0.0);
        let mut t0 = Trial::new(0, 0, vec![("x".into(), Value::Num(0.1))], 0.0, None);
        t0.complete(1.0, 1.0).unwrap();
        s.trials.push(t0);
        s.note_scored(0, 8);
        let w1 = s.obs_window(8);
        assert_eq!(w1.len(), 1);
        let mut t1 = Trial::new(1, 1, vec![("x".into(), Value::Num(0.2))], 0.0, None);
        t1.complete(2.0, 2.0).unwrap();
        s.trials.push(t1);
        s.note_scored(1, 8);
        // Old snapshot (held by an "in-flight ask") is untouched; the new
        // one sees the appended observation.
        assert_eq!(w1.len(), 1);
        let w2 = s.obs_window(8);
        assert_eq!(w2.len(), 2);
        assert_eq!(w2[1].value, 2.0);
        assert_eq!(snap_of(&mut s, 8), window_of(&s, 8));
    }

    #[test]
    fn note_scored_out_of_order_rebuilds_correctly() {
        let mut s = Study::new(1, def(), 0.0);
        // Two running trials inserted in order 0, 1.
        for i in 0..2u64 {
            s.trials.push(Trial::new(
                i,
                i,
                vec![("x".into(), Value::Num(i as f64))],
                0.0,
                None,
            ));
        }
        // Trial 1 completes first, then trial 0: scored order must stay
        // insert order (0 then 1), matching `scored()`.
        s.trials[1].complete(10.0, 1.0).unwrap();
        s.note_scored(1, 8);
        let _ = s.obs_window(8);
        s.trials[0].complete(20.0, 2.0).unwrap();
        s.note_scored(0, 8);
        let snap = snap_of(&mut s, 8);
        assert_eq!(snap, window_of(&s, 8));
        assert_eq!(s.obs_window(8)[0].value, 20.0);
        assert_eq!(s.obs_window(8)[1].value, 10.0);
    }

    #[test]
    fn obs_window_epoch_reuses_arc() {
        let mut s = Study::new(1, def(), 0.0);
        let mut t = Trial::new(0, 0, vec![("x".into(), Value::Num(0.5))], 0.0, None);
        t.complete(1.0, 1.0).unwrap();
        s.trials.push(t);
        s.note_scored(0, 8);
        let a = s.obs_window(8);
        let b = s.obs_window(8);
        assert!(Arc::ptr_eq(&a, &b), "same epoch must share the snapshot");
        s.trials[0].params = vec![("x".into(), Value::Num(0.9))]; // not visible
        assert_eq!(s.obs_window(8)[0].params[0].1.as_f64(), Some(0.5));
    }

    #[test]
    fn summary_counts() {
        let mut s = Study::new(4, def(), 0.0);
        let t = Trial::new(0, 0, vec![("x".into(), Value::Num(0.5))], 0.0, None);
        s.trials.push(t);
        let j = s.summary_json();
        assert_eq!(j.get("n_trials").as_i64(), Some(1));
        assert_eq!(j.get("n_running").as_i64(), Some(1));
        assert_eq!(j.get("n_completed").as_i64(), Some(0));
        assert!(j.get("best_value").is_null());
    }
}

//! The §4 workload: GAN training trials executed through PJRT.
//!
//! [`data`] synthesizes the conditional "detector response" ground truth
//! (the stand-in for LHCb simulation data — same formulas as
//! `python/compile/model.py::synthetic_batch`); [`GanTrainer`] drives a
//! full trial: initialize parameters from the manifest, run train-step
//! executions with HOPAAS-suggested hyperparameters, report intermediate
//! Wasserstein distances for pruning, and return the final objective.

pub mod data;

use crate::rng::Rng;
use crate::runtime::{literal_f32, literal_scalar, Runtime, RuntimeError, Variant};
use std::sync::Arc;

/// Continuous hyperparameters of one trial (suggested by HOPAAS).
#[derive(Clone, Copy, Debug)]
pub struct GanHyper {
    pub lr_g: f32,
    pub lr_d: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub leak: f32,
}

impl Default for GanHyper {
    /// The "previous results" baseline configuration (E6 compares the
    /// campaign's best against this).
    fn default() -> Self {
        GanHyper { lr_g: 1e-3, lr_d: 1e-3, beta1: 0.9, beta2: 0.999, leak: 0.01 }
    }
}

/// A GAN training trial bound to one compiled architecture variant.
pub struct GanTrainer {
    runtime: Arc<Runtime>,
    variant: Variant,
    /// Flat train state (params + adam m + v + t) as literals.
    state: Vec<xla::Literal>,
    rng: Rng,
    pub steps_done: u64,
}

impl GanTrainer {
    /// Initialize with He-init weights from `seed` (deterministic per
    /// trial, so a preempted trial can be re-run bit-identically).
    pub fn new(
        runtime: Arc<Runtime>,
        width: u64,
        depth: u64,
        seed: u64,
    ) -> Result<GanTrainer, RuntimeError> {
        let variant = runtime
            .manifest
            .variant(width, depth)
            .ok_or_else(|| {
                RuntimeError::Manifest(format!("no compiled variant {width}x{depth}"))
            })?
            .clone();
        let mut rng = Rng::new(seed);
        let mut state = Vec::with_capacity(variant.n_state);
        // Params: He init for matrices, zeros for biases.
        for shape in &variant.param_shapes {
            let n: usize = shape.iter().product();
            let mut buf = vec![0f32; n];
            if shape.len() == 2 {
                let std = (2.0 / shape[0] as f64).sqrt() as f32;
                rng.fill_normal_f32(&mut buf);
                for v in buf.iter_mut() {
                    *v *= std;
                }
            }
            state.push(literal_f32(shape, &buf)?);
        }
        // Adam m and v: zeros.
        for _ in 0..2 {
            for shape in &variant.param_shapes {
                let n: usize = shape.iter().product();
                state.push(literal_f32(shape, &vec![0f32; n])?);
            }
        }
        // t.
        state.push(literal_f32(&[], &[0.0])?);
        debug_assert_eq!(state.len(), variant.n_state);
        Ok(GanTrainer { runtime, variant, state, rng, steps_done: 0 })
    }

    /// Variant descriptor.
    pub fn variant(&self) -> &Variant {
        &self.variant
    }

    /// Run `n` adversarial steps; returns the last (loss_d, loss_g).
    pub fn train(&mut self, n: u64, hp: &GanHyper) -> Result<(f32, f32), RuntimeError> {
        let exe = self.runtime.load(&self.variant.train_file)?;
        let m = &self.runtime.manifest;
        let mut last = (f32::NAN, f32::NAN);
        for _ in 0..n {
            let (cond, real) = data::batch(&mut self.rng, m.batch);
            let mut noise = vec![0f32; m.batch * m.latent_dim];
            self.rng.fill_normal_f32(&mut noise);

            let mut inputs: Vec<xla::Literal> = Vec::with_capacity(self.variant.n_state + 8);
            // State moves in; it is replaced by the outputs below.
            inputs.append(&mut self.state);
            inputs.push(literal_f32(&[m.batch, m.cond_dim], &cond)?);
            inputs.push(literal_f32(&[m.batch, m.feat_dim], &real)?);
            inputs.push(literal_f32(&[m.batch, m.latent_dim], &noise)?);
            for s in [hp.lr_g, hp.lr_d, hp.beta1, hp.beta2, hp.leak] {
                inputs.push(literal_f32(&[], &[s])?);
            }
            let mut out = self.runtime.execute(&exe, &inputs)?;
            let loss_g = literal_scalar(&out.pop().unwrap())?;
            let loss_d = literal_scalar(&out.pop().unwrap())?;
            self.state = out;
            last = (loss_d, loss_g);
            self.steps_done += 1;
        }
        Ok(last)
    }

    /// Evaluate with the default slope (tests/smoke use only — real
    /// trials must pass the slope they trained with).
    pub fn evaluate(&mut self) -> Result<f32, RuntimeError> {
        self.evaluate_with_leak(0.1)
    }

    /// Evaluate the current generator: mean per-feature Wasserstein-1
    /// against a fresh reference batch — the objective HOPAAS minimizes.
    /// `leak` must match the slope the trial trained with.
    pub fn evaluate_with_leak(&mut self, leak: f32) -> Result<f32, RuntimeError> {
        let exe = self.runtime.load(&self.variant.eval_file)?;
        let m = &self.runtime.manifest;
        let (cond, real) = data::batch(&mut self.rng, m.eval_batch);
        let mut noise = vec![0f32; m.eval_batch * m.latent_dim];
        self.rng.fill_normal_f32(&mut noise);
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(self.variant.n_gen_arrays + 4);
        for (i, lit) in self.state[..self.variant.n_gen_arrays].iter().enumerate() {
            let shape = &self.variant.param_shapes[i];
            inputs.push(literal_f32(shape, &crate::runtime::literal_to_vec(lit)?)?);
        }
        inputs.push(literal_f32(&[m.eval_batch, m.cond_dim], &cond)?);
        inputs.push(literal_f32(&[m.eval_batch, m.feat_dim], &real)?);
        inputs.push(literal_f32(&[m.eval_batch, m.latent_dim], &noise)?);
        inputs.push(literal_f32(&[], &[leak])?);
        let out = self.runtime.execute(&exe, &inputs)?;
        Ok(literal_scalar(&out[0])?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Arc::new(Runtime::open(dir).unwrap()))
    }

    #[test]
    fn trainer_initializes_state() {
        let Some(rt) = runtime() else { return };
        let t = GanTrainer::new(rt, 32, 2, 7).unwrap();
        assert_eq!(t.state.len(), t.variant.n_state);
    }

    #[test]
    fn unknown_variant_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(GanTrainer::new(rt, 999, 9, 0).is_err());
    }

    #[test]
    fn training_reduces_wasserstein() {
        let Some(rt) = runtime() else { return };
        let mut t = GanTrainer::new(rt, 32, 2, 42).unwrap();
        let hp = GanHyper { lr_g: 2e-3, lr_d: 2e-3, beta1: 0.5, beta2: 0.9, leak: 0.1 };
        let before = t.evaluate_with_leak(hp.leak).unwrap();
        let (loss_d, loss_g) = t.train(40, &hp).unwrap();
        assert!(loss_d.is_finite() && loss_g.is_finite());
        let after = t.evaluate_with_leak(hp.leak).unwrap();
        assert!(
            after < before,
            "W1 should improve: before={before} after={after}"
        );
        assert_eq!(t.steps_done, 40);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(rt) = runtime() else { return };
        let hp = GanHyper::default();
        let mut a = GanTrainer::new(rt.clone(), 32, 2, 5).unwrap();
        let mut b = GanTrainer::new(rt, 32, 2, 5).unwrap();
        let la = a.train(3, &hp).unwrap();
        let lb = b.train(3, &hp).unwrap();
        assert_eq!(la, lb);
    }
}

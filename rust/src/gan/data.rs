//! Synthetic conditional "detector response" data — the Rust twin of
//! `python/compile/model.py::synthetic_batch` (same formulas; see
//! DESIGN.md §3 for why this substitution preserves the paper's
//! behaviour). Conditions mimic normalized kinematics (p, η, nTracks);
//! responses are correlated, heteroscedastic, and condition-dependent.

use crate::rng::Rng;

/// Condition dimensionality (must match the manifest).
pub const COND_DIM: usize = 3;
/// Response dimensionality.
pub const FEAT_DIM: usize = 4;

/// Draw a batch: returns `(cond, real)` as row-major flat vecs of shape
/// `(batch, COND_DIM)` and `(batch, FEAT_DIM)`.
pub fn batch(rng: &mut Rng, batch: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cond = vec![0f32; batch * COND_DIM];
    let mut real = vec![0f32; batch * FEAT_DIM];
    rng.fill_uniform_f32(&mut cond, 0.0, 1.0);
    for i in 0..batch {
        let p = cond[i * COND_DIM] as f64;
        let eta = cond[i * COND_DIM + 1] as f64;
        let ntr = cond[i * COND_DIM + 2] as f64;
        let s = 0.1 + 0.2 * ntr;
        let e0 = rng.normal();
        let e1 = rng.normal();
        let e2 = rng.normal();
        let e3 = rng.normal();
        let mu0 = 2.0 * p - 1.0 + 0.5 * (3.0 * eta).sin();
        let mu1 = p * eta;
        let mu2 = 0.5 * (3.0 * p).cos() + 0.3 * ntr;
        let mu3 = 0.5 * mu0 + mu1;
        real[i * FEAT_DIM] = (mu0 + s * e0) as f32;
        real[i * FEAT_DIM + 1] = (mu1 + s * e1) as f32;
        real[i * FEAT_DIM + 2] = (mu2 + s * e2) as f32;
        real[i * FEAT_DIM + 3] = (mu3 + s * e3 + 0.3 * s * e0) as f32;
    }
    (cond, real)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut rng = Rng::new(1);
        let (cond, real) = batch(&mut rng, 512);
        assert_eq!(cond.len(), 512 * COND_DIM);
        assert_eq!(real.len(), 512 * FEAT_DIM);
        assert!(cond.iter().all(|&c| (0.0..=1.0).contains(&c)));
        assert!(real.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn condition_dependence() {
        // mu0 ≈ 2p-1: high-p rows must have larger feature 0.
        let mut rng = Rng::new(2);
        let (cond, real) = batch(&mut rng, 8192);
        let (mut lo, mut hi, mut nlo, mut nhi) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..8192 {
            let p = cond[i * COND_DIM];
            let y0 = real[i * FEAT_DIM] as f64;
            if p < 0.3 {
                lo += y0;
                nlo += 1;
            } else if p > 0.7 {
                hi += y0;
                nhi += 1;
            }
        }
        assert!(hi / nhi as f64 - lo / nlo as f64 > 0.5);
    }

    #[test]
    fn correlated_features() {
        // y3 shares e0 noise and mu0: corr(y0, y3) > 0.3.
        let mut rng = Rng::new(3);
        let (_, real) = batch(&mut rng, 8192);
        let n = 8192;
        let (mut m0, mut m3) = (0.0f64, 0.0f64);
        for i in 0..n {
            m0 += real[i * FEAT_DIM] as f64;
            m3 += real[i * FEAT_DIM + 3] as f64;
        }
        m0 /= n as f64;
        m3 /= n as f64;
        let (mut c, mut v0, mut v3) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..n {
            let a = real[i * FEAT_DIM] as f64 - m0;
            let b = real[i * FEAT_DIM + 3] as f64 - m3;
            c += a * b;
            v0 += a * a;
            v3 += b * b;
        }
        let r = c / (v0.sqrt() * v3.sqrt());
        assert!(r > 0.3, "corr={r}");
    }

    #[test]
    fn deterministic_per_seed() {
        let (c1, r1) = batch(&mut Rng::new(9), 64);
        let (c2, r2) = batch(&mut Rng::new(9), 64);
        assert_eq!(c1, c2);
        assert_eq!(r1, r2);
    }
}

//! End-to-end request tracing.
//!
//! Every API request gets an **`X-Request-Id`** — accepted from the
//! client when present (so worker retries and requeues keep one id
//! across attempts), generated otherwise — and a [`SpanCtx`] that
//! follows the request through the stack: HTTP accept → router →
//! service → engine (fleet admission, shard lock, sampler fit) →
//! group-commit WAL (queue wait / shared fsync / ack wait, attributed
//! per request by the writer's ack) → materialized-view publish. Each
//! stage records a `(offset, duration)` pair into a fixed array inside
//! the span — pure stack/TLS writes, no allocation, no locks — and the
//! span is flushed into the [`Tracer`]'s striped ring buffer only when
//! the request *finishes*, and only if it was head-sampled
//! (`--trace-sample`) or slower than the slow-op threshold
//! (`--trace-slow-ms`, always retained regardless of sampling).
//!
//! The ring buffer is fixed-capacity ([`TracerConfig::capacity`],
//! `--trace-capacity`, 0 disables tracing entirely) and pre-allocated:
//! a flushed span overwrites the oldest slot of its stripe, every field
//! is a fixed-size copy (`ReqId`, [`Tag`], the stage array), so the
//! steady state performs zero heap allocation. Retained traces are
//! served by `GET /api/trace/recent` and `GET /api/trace/{id}`; the
//! slowest recent operation per kind is exported as a
//! `hopaas_slow_trace_seconds{api,trace_id}` exemplar next to the
//! latency histograms in `/metrics`; and `--log-json` emits one
//! structured log line per retained request with
//! tenant/study/worker/site attribution.
//!
//! Propagation uses a thread-local current-span slot rather than
//! threading a context argument through every engine signature: request
//! handling is synchronous on one server worker thread (the WAL ack and
//! the sampler fit both return to the calling thread), so
//! [`install`]/[`take`] around the router dispatch make the span
//! visible to every layer underneath without touching their APIs.

use crate::json::Value;
use crate::rng;
use crate::sync::MutexExt;
use std::cell::RefCell;
use std::fmt;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Stage slots per span. A typical ask touches ~8 (admission, two shard
/// locks, sampler fit, three WAL stages, view publish); extras (e.g.
/// batched asks re-locking) spill into the overflow counter rather than
/// growing the span.
pub const MAX_STAGES: usize = 16;

/// Bytes kept of a request id (client-supplied ids are truncated to
/// this; generated ids are 20 bytes).
const ID_CAP: usize = 48;

/// Bytes kept of a tenant/worker/site attribution tag.
const TAG_CAP: usize = 24;

// ---------------------------------------------------------------------------
// ReqId
// ---------------------------------------------------------------------------

/// A request id: fixed-size, `Copy`, header- and JSON-safe by
/// construction (sanitized on parse, hex on generation).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct ReqId {
    buf: [u8; ID_CAP],
    len: u8,
}

impl ReqId {
    /// Sanitize a client-supplied header value: keep `[A-Za-z0-9._:-]`,
    /// truncate to [`ID_CAP`]. `None` when nothing survives (the server
    /// then generates an id instead).
    pub fn parse(raw: &str) -> Option<ReqId> {
        let mut buf = [0u8; ID_CAP];
        let mut len = 0usize;
        for &b in raw.trim().as_bytes() {
            if len == ID_CAP {
                break;
            }
            if b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.' | b':') {
                buf[len] = b;
                len += 1;
            }
        }
        if len == 0 {
            None
        } else {
            Some(ReqId { buf, len: len as u8 })
        }
    }

    /// Generate a fresh id (`req-` + 16 hex digits) from the wall clock
    /// and a process-wide counter — unique enough to stitch logs across
    /// services without coordination.
    pub fn generate(counter: u64) -> ReqId {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let h = rng::mix(nanos, counter);
        let mut buf = [0u8; ID_CAP];
        buf[..4].copy_from_slice(b"req-");
        const HEX: &[u8; 16] = b"0123456789abcdef";
        for i in 0..16 {
            buf[4 + i] = HEX[((h >> (60 - 4 * i)) & 0xf) as usize];
        }
        ReqId { buf, len: 20 }
    }

    pub fn as_str(&self) -> &str {
        std::str::from_utf8(&self.buf[..self.len as usize]).unwrap_or("")
    }
}

impl fmt::Display for ReqId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for ReqId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ReqId({})", self.as_str())
    }
}

// ---------------------------------------------------------------------------
// Tag — fixed-size attribution string (tenant / worker / site)
// ---------------------------------------------------------------------------

/// Fixed-capacity attribution tag. Copyable so flushing a span into the
/// ring buffer never allocates.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Tag {
    buf: [u8; TAG_CAP],
    len: u8,
}

impl Tag {
    pub const EMPTY: Tag = Tag { buf: [0; TAG_CAP], len: 0 };

    pub fn new(s: &str) -> Tag {
        let mut buf = [0u8; TAG_CAP];
        let bytes = s.as_bytes();
        // Truncate on a char boundary so as_str never sees torn UTF-8.
        let mut take = bytes.len().min(TAG_CAP);
        while take > 0 && !s.is_char_boundary(take) {
            take -= 1;
        }
        buf[..take].copy_from_slice(&bytes[..take]);
        Tag { buf, len: take as u8 }
    }

    pub fn as_str(&self) -> &str {
        std::str::from_utf8(&self.buf[..self.len as usize]).unwrap_or("")
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

// ---------------------------------------------------------------------------
// OpKind and Stage
// ---------------------------------------------------------------------------

/// Operation class of a traced request — the `kind` filter of
/// `/api/trace/recent` and the exemplar grouping in `/metrics`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpKind {
    Ask,
    Tell,
    Prune,
    Fail,
    Read,
    Other,
}

impl OpKind {
    pub const ALL: [OpKind; 6] = [
        OpKind::Ask,
        OpKind::Tell,
        OpKind::Prune,
        OpKind::Fail,
        OpKind::Read,
        OpKind::Other,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Ask => "ask",
            OpKind::Tell => "tell",
            OpKind::Prune => "prune",
            OpKind::Fail => "fail",
            OpKind::Read => "read",
            OpKind::Other => "other",
        }
    }

    pub fn parse(s: &str) -> Option<OpKind> {
        OpKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    fn index(self) -> usize {
        match self {
            OpKind::Ask => 0,
            OpKind::Tell => 1,
            OpKind::Prune => 2,
            OpKind::Fail => 3,
            OpKind::Read => 4,
            OpKind::Other => 5,
        }
    }
}

/// Classify a request into an op kind from its method and path. Mutation
/// endpoints are matched by their terminal segment; everything read-only
/// is `Read`.
pub fn classify(method: &str, path: &str) -> OpKind {
    let path = path.split('?').next().unwrap_or(path);
    if method == "GET" || method == "HEAD" {
        return OpKind::Read;
    }
    // The op verb is not always terminal: token-suffixed routes like
    // `/api/ask/{token}` put it mid-path, so scan every segment.
    for seg in path.split('/') {
        match seg {
            "ask" => return OpKind::Ask,
            "tell" => return OpKind::Tell,
            "should_prune" | "prune" => return OpKind::Prune,
            "fail" => return OpKind::Fail,
            _ => {}
        }
    }
    OpKind::Other
}

/// A pipeline stage whose wait/work time is attributed to the request.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Stage {
    /// Fleet admission (quota + fair-share) wait in `ask`.
    Admission,
    /// Wait to acquire the study's shard lock.
    ShardLock,
    /// Sampler fit (model rebuild) outside the shard lock.
    SamplerFit,
    /// WAL: enqueue → the writer starting the commit batch.
    WalQueue,
    /// WAL: the shared fsync of the commit batch this request joined.
    WalFsync,
    /// WAL: full append roundtrip (enqueue → durable ack received).
    WalAck,
    /// Materialized-view publish under the shard lock.
    ViewPublish,
    /// Replication: collecting a batch run from the primary's log
    /// buffer (the `/api/repl/log` read side).
    ReplFetch,
    /// Replication: follower-side apply of a shipped batch (local
    /// append + replay + view rebuild).
    ReplApply,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::ShardLock => "shard_lock",
            Stage::SamplerFit => "sampler_fit",
            Stage::WalQueue => "wal_queue",
            Stage::WalFsync => "wal_fsync",
            Stage::WalAck => "wal_ack",
            Stage::ViewPublish => "view_publish",
            Stage::ReplFetch => "repl_fetch",
            Stage::ReplApply => "repl_apply",
        }
    }
}

/// One recorded stage: when it happened (µs offset from request start)
/// and how long it took.
#[derive(Clone, Copy)]
pub struct StageRec {
    stage: Stage,
    at_us: u32,
    dur_us: u32,
}

impl StageRec {
    const EMPTY: StageRec = StageRec { stage: Stage::Admission, at_us: 0, dur_us: 0 };
}

// ---------------------------------------------------------------------------
// SpanCtx + thread-local propagation
// ---------------------------------------------------------------------------

/// The live trace of one in-flight request. Fully fixed-size: creating,
/// mutating, and flushing one performs no heap allocation.
pub struct SpanCtx {
    id: ReqId,
    kind: OpKind,
    start: Instant,
    start_unix_ms: u64,
    stages: [StageRec; MAX_STAGES],
    n_stages: u8,
    /// Stages dropped because the fixed array filled.
    overflow: u8,
    study: u64,
    tenant: Tag,
    worker: Tag,
    site: Tag,
    sampled: bool,
}

impl SpanCtx {
    fn new(id: ReqId, kind: OpKind, sampled: bool) -> SpanCtx {
        let start_unix_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        SpanCtx {
            id,
            kind,
            start: Instant::now(),
            start_unix_ms,
            stages: [StageRec::EMPTY; MAX_STAGES],
            n_stages: 0,
            overflow: 0,
            study: 0,
            tenant: Tag::EMPTY,
            worker: Tag::EMPTY,
            site: Tag::EMPTY,
            sampled,
        }
    }

    pub fn id(&self) -> ReqId {
        self.id
    }

    fn record(&mut self, stage: Stage, dur_us: u64) {
        let n = self.n_stages as usize;
        if n == MAX_STAGES {
            self.overflow = self.overflow.saturating_add(1);
            return;
        }
        let at = self.start.elapsed().as_micros();
        self.stages[n] = StageRec {
            stage,
            at_us: at.min(u32::MAX as u128) as u32,
            dur_us: dur_us.min(u32::MAX as u64) as u32,
        };
        self.n_stages += 1;
    }
}

thread_local! {
    static CURRENT: RefCell<Option<SpanCtx>> = const { RefCell::new(None) };
}

/// Make `span` the current request on this thread (server worker, right
/// before router dispatch).
pub fn install(span: SpanCtx) {
    CURRENT.with(|c| *c.borrow_mut() = Some(span));
}

/// Remove and return the current span (server worker, right after
/// dispatch returns).
pub fn take() -> Option<SpanCtx> {
    CURRENT.with(|c| c.borrow_mut().take())
}

/// Whether a span is being traced on this thread.
pub fn active() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// The current request id, if a span is active — what the WAL writer
/// ledger and outgoing log lines attribute to.
pub fn current_id() -> Option<ReqId> {
    CURRENT.with(|c| c.borrow().as_ref().map(|s| s.id))
}

/// Record a stage with a measured duration.
pub fn stage(stage: Stage, dur: Duration) {
    stage_us(stage, dur.as_micros().min(u64::MAX as u128) as u64);
}

/// Record a stage with a duration already in microseconds (WAL ack
/// attribution arrives this way).
pub fn stage_us(st: Stage, dur_us: u64) {
    CURRENT.with(|c| {
        if let Some(span) = c.borrow_mut().as_mut() {
            span.record(st, dur_us);
        }
    });
}

/// Attribute the request to a study.
pub fn set_study(id: u64) {
    CURRENT.with(|c| {
        if let Some(span) = c.borrow_mut().as_mut() {
            span.study = id;
        }
    });
}

/// Attribute the request to a tenant.
pub fn set_tenant(tenant: &str) {
    CURRENT.with(|c| {
        if let Some(span) = c.borrow_mut().as_mut() {
            span.tenant = Tag::new(tenant);
        }
    });
}

/// Attribute the request to a worker.
pub fn set_worker(worker: &str) {
    CURRENT.with(|c| {
        if let Some(span) = c.borrow_mut().as_mut() {
            span.worker = Tag::new(worker);
        }
    });
}

/// Attribute the request to a site.
pub fn set_site(site: &str) {
    CURRENT.with(|c| {
        if let Some(span) = c.borrow_mut().as_mut() {
            span.site = Tag::new(site);
        }
    });
}

// ---------------------------------------------------------------------------
// Tracer — the striped ring buffer + exemplars + structured log
// ---------------------------------------------------------------------------

/// Tracing configuration (the `--trace-*` / `--log-json` flags).
#[derive(Clone, Copy, Debug)]
pub struct TracerConfig {
    /// Total retained-trace slots across all stripes. 0 disables
    /// tracing entirely (spans are never created).
    pub capacity: usize,
    /// Head-sampling probability in `[0, 1]`: the fraction of requests
    /// whose trace is retained (and logged) regardless of latency.
    pub sample: f64,
    /// Requests at least this slow are always retained and logged, even
    /// when head sampling skipped them. 0 marks nothing as slow.
    pub slow_ms: u64,
    /// Emit one structured JSON log line per retained request.
    pub log_json: bool,
}

impl Default for TracerConfig {
    fn default() -> Self {
        TracerConfig { capacity: 2048, sample: 1.0, slow_ms: 250, log_json: false }
    }
}

/// A retained trace in the ring buffer. Fixed-size and `Copy` — slot
/// reuse is a plain overwrite.
#[derive(Clone, Copy)]
struct TraceRecord {
    used: bool,
    seq: u64,
    id: ReqId,
    kind: OpKind,
    status: u16,
    slow: bool,
    start_unix_ms: u64,
    total_us: u64,
    study: u64,
    tenant: Tag,
    worker: Tag,
    site: Tag,
    stages: [StageRec; MAX_STAGES],
    n_stages: u8,
    overflow: u8,
}

impl TraceRecord {
    const EMPTY: TraceRecord = TraceRecord {
        used: false,
        seq: 0,
        id: ReqId { buf: [0; ID_CAP], len: 0 },
        kind: OpKind::Other,
        status: 0,
        slow: false,
        start_unix_ms: 0,
        total_us: 0,
        study: 0,
        tenant: Tag::EMPTY,
        worker: Tag::EMPTY,
        site: Tag::EMPTY,
        stages: [StageRec::EMPTY; MAX_STAGES],
        n_stages: 0,
        overflow: 0,
    };

    fn render(&self, full: bool) -> Value {
        let mut o = Value::obj();
        o.set("id", self.id.as_str())
            .set("kind", self.kind.name())
            .set("status", self.status as i64)
            .set("slow", self.slow)
            .set("start_unix_ms", self.start_unix_ms)
            .set("total_us", self.total_us);
        if self.study != 0 {
            o.set("study", self.study);
        }
        if !self.tenant.is_empty() {
            o.set("tenant", self.tenant.as_str());
        }
        if !self.worker.is_empty() {
            o.set("worker", self.worker.as_str());
        }
        if !self.site.is_empty() {
            o.set("site", self.site.as_str());
        }
        if full {
            let mut stages = Vec::new();
            for rec in &self.stages[..self.n_stages as usize] {
                let mut s = Value::obj();
                s.set("stage", rec.stage.name())
                    .set("at_us", rec.at_us as u64)
                    .set("dur_us", rec.dur_us as u64);
                stages.push(Value::Obj(s));
            }
            o.set("stages", Value::Arr(stages));
            if self.overflow > 0 {
                o.set("stages_dropped", self.overflow as u64);
            }
        } else {
            o.set("stages", self.n_stages as u64);
        }
        Value::Obj(o)
    }
}

struct Stripe {
    slots: Vec<TraceRecord>,
    next: usize,
}

/// Per-kind slow-op exemplar: the slowest request of the current
/// rolling window, exported next to the latency histograms.
struct SlowSlot {
    id: ReqId,
    seconds: f64,
    present: bool,
    /// Finishes seen this window; the slot resets every
    /// [`EXEMPLAR_WINDOW`] so a one-off spike ages out.
    window: u32,
}

const EXEMPLAR_WINDOW: u32 = 4096;

/// Number of ring stripes — bounds flush contention across server
/// worker threads without per-slot locks.
const STRIPES: usize = 8;

/// The tracing subsystem: owns the retained-trace ring buffer, the
/// slow-op exemplars, and the structured-log writer. One per engine,
/// shared with the HTTP server.
pub struct Tracer {
    config: TracerConfig,
    stripes: Vec<Mutex<Stripe>>,
    /// Flush sequence — total ordering of retained traces.
    seq: AtomicU64,
    /// Id-generation / sampling counter.
    ids: AtomicU64,
    /// Requests finished (traced at all, retained or not).
    finished: AtomicU64,
    /// Requests whose trace was retained in the ring.
    retained: AtomicU64,
    /// Requests that crossed the slow threshold.
    slow: AtomicU64,
    exemplars: Vec<Mutex<SlowSlot>>,
}

impl Tracer {
    pub fn new(config: TracerConfig) -> Tracer {
        let capacity = config.capacity;
        let n_stripes = if capacity == 0 { 0 } else { STRIPES.min(capacity) };
        let mut stripes = Vec::with_capacity(n_stripes);
        for i in 0..n_stripes {
            // Spread the capacity across stripes, remainder to the first.
            let base = capacity / n_stripes;
            let extra = usize::from(i < capacity % n_stripes);
            stripes.push(Mutex::new(Stripe {
                slots: vec![TraceRecord::EMPTY; base + extra],
                next: 0,
            }));
        }
        let exemplars = OpKind::ALL
            .iter()
            .map(|_| {
                Mutex::new(SlowSlot {
                    id: ReqId { buf: [0; ID_CAP], len: 0 },
                    seconds: 0.0,
                    present: false,
                    window: 0,
                })
            })
            .collect();
        Tracer {
            config,
            stripes,
            seq: AtomicU64::new(0),
            ids: AtomicU64::new(0),
            finished: AtomicU64::new(0),
            retained: AtomicU64::new(0),
            slow: AtomicU64::new(0),
            exemplars,
        }
    }

    /// Whether tracing is on at all (`--trace-capacity 0` turns the
    /// whole subsystem off; the server then skips span creation).
    pub fn enabled(&self) -> bool {
        self.config.capacity > 0
    }

    pub fn config(&self) -> &TracerConfig {
        &self.config
    }

    /// Start a span for a request: reuse the client's sanitized
    /// `X-Request-Id` or generate one, and take the head-sampling
    /// decision (deterministic in the request counter).
    pub fn begin(&self, incoming: Option<&str>, kind: OpKind) -> SpanCtx {
        let n = self.ids.fetch_add(1, Ordering::Relaxed);
        let id = incoming.and_then(ReqId::parse).unwrap_or_else(|| ReqId::generate(n));
        let sampled = if self.config.sample >= 1.0 {
            true
        } else if self.config.sample <= 0.0 {
            false
        } else {
            let roll = rng::mix(0x7472_6163_655f_6964, n) % 1_000_000;
            (roll as f64) < self.config.sample * 1e6
        };
        SpanCtx::new(id, kind, sampled)
    }

    /// Finish a span: decide retention (sampled ∨ slow), flush into the
    /// ring, feed the exemplar slot, emit the log line. Runs after the
    /// response is built — never on the request's critical path stages.
    pub fn finish(&self, span: SpanCtx, status: u16) {
        self.finished.fetch_add(1, Ordering::Relaxed);
        let total_us = span.start.elapsed().as_micros().min(u64::MAX as u128) as u64;
        let slow = self.config.slow_ms > 0 && total_us >= self.config.slow_ms * 1000;
        if slow {
            self.slow.fetch_add(1, Ordering::Relaxed);
        }
        self.note_exemplar(span.kind, span.id, total_us, slow);
        if !(span.sampled || slow) {
            return;
        }
        self.retained.fetch_add(1, Ordering::Relaxed);
        if !self.stripes.is_empty() {
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            let stripe = &self.stripes[(seq as usize) % self.stripes.len()];
            let mut g = stripe.lock_safe();
            let pos = g.next;
            g.next = (g.next + 1) % g.slots.len().max(1);
            let slot = &mut g.slots[pos];
            *slot = TraceRecord {
                used: true,
                seq,
                id: span.id,
                kind: span.kind,
                status,
                slow,
                start_unix_ms: span.start_unix_ms,
                total_us,
                study: span.study,
                tenant: span.tenant,
                worker: span.worker,
                site: span.site,
                stages: span.stages,
                n_stages: span.n_stages,
                overflow: span.overflow,
            };
        }
        if self.config.log_json {
            self.log_line(&span, status, total_us, slow);
        }
    }

    /// Track the slowest request of the rolling window for `kind`. Slow
    /// requests always displace a faster exemplar; the window reset
    /// keeps a historic spike from pinning the slot forever.
    fn note_exemplar(&self, kind: OpKind, id: ReqId, total_us: u64, slow: bool) {
        let mut slot = self.exemplars[kind.index()].lock_safe();
        slot.window += 1;
        if slot.window >= EXEMPLAR_WINDOW {
            slot.window = 0;
            slot.present = false;
        }
        let seconds = total_us as f64 / 1e6;
        if !slot.present || seconds > slot.seconds || (slow && seconds >= slot.seconds) {
            slot.id = id;
            slot.seconds = seconds;
            slot.present = true;
        }
    }

    /// One structured JSON log line per retained request, on stderr.
    fn log_line(&self, span: &SpanCtx, status: u16, total_us: u64, slow: bool) {
        let mut o = Value::obj();
        o.set("ts_unix_ms", span.start_unix_ms)
            .set("level", if slow { "warn" } else { "info" })
            .set("request_id", span.id.as_str())
            .set("kind", span.kind.name())
            .set("status", status as i64)
            .set("total_us", total_us)
            .set("slow", slow);
        if span.study != 0 {
            o.set("study", span.study);
        }
        if !span.tenant.is_empty() {
            o.set("tenant", span.tenant.as_str());
        }
        if !span.worker.is_empty() {
            o.set("worker", span.worker.as_str());
        }
        if !span.site.is_empty() {
            o.set("site", span.site.as_str());
        }
        let mut stages = Value::obj();
        for rec in &span.stages[..span.n_stages as usize] {
            // Repeated stages (e.g. the two shard-lock sections of an
            // ask) accumulate under one key.
            let prior = stages
                .get(rec.stage.name())
                .and_then(|v| v.as_u64())
                .unwrap_or(0);
            stages.set(rec.stage.name(), prior + rec.dur_us as u64);
        }
        o.set("stages_us", Value::Obj(stages));
        let line = Value::Obj(o).to_string();
        let stderr = std::io::stderr();
        let mut w = stderr.lock();
        let _ = writeln!(w, "{line}");
    }

    /// Full stage timeline of a retained trace, newest match first.
    pub fn get(&self, id: &str) -> Option<Value> {
        let mut best: Option<TraceRecord> = None;
        for stripe in &self.stripes {
            let g = stripe.lock_safe();
            for rec in &g.slots {
                if rec.used && rec.id.as_str() == id {
                    match &best {
                        Some(b) if b.seq >= rec.seq => {}
                        _ => best = Some(*rec),
                    }
                }
            }
        }
        best.map(|rec| rec.render(true))
    }

    /// Recent retained traces, newest first, optionally filtered by op
    /// kind and study id.
    pub fn recent(&self, limit: usize, kind: Option<OpKind>, study: Option<u64>) -> Value {
        let mut rows: Vec<TraceRecord> = Vec::new();
        for stripe in &self.stripes {
            let g = stripe.lock_safe();
            for rec in &g.slots {
                if !rec.used {
                    continue;
                }
                if let Some(k) = kind {
                    if rec.kind != k {
                        continue;
                    }
                }
                if let Some(s) = study {
                    if rec.study != s {
                        continue;
                    }
                }
                rows.push(*rec);
            }
        }
        rows.sort_by(|a, b| b.seq.cmp(&a.seq));
        rows.truncate(limit);
        Value::Arr(rows.iter().map(|r| r.render(false)).collect())
    }

    /// Tracer counters for `/api/stats`.
    pub fn stats_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("enabled", self.enabled())
            .set("capacity", self.config.capacity as u64)
            .set("sample", self.config.sample)
            .set("slow_ms", self.config.slow_ms)
            .set("finished", self.finished.load(Ordering::Relaxed))
            .set("retained", self.retained.load(Ordering::Relaxed))
            .set("slow", self.slow.load(Ordering::Relaxed));
        Value::Obj(o)
    }

    /// Append the `hopaas_slow_trace_seconds` exemplar series to a
    /// `/metrics` scrape: per op kind, the slowest request of the
    /// current window with its trace id as a label — the bridge from an
    /// aggregate histogram to one inspectable `/api/trace/{id}`.
    pub fn render_exemplars(&self, out: &mut String) {
        out.push_str(
            "# HELP hopaas_slow_trace_seconds Slowest recent request per op kind; \
             trace_id resolves via /api/trace/{id}.\n",
        );
        out.push_str("# TYPE hopaas_slow_trace_seconds gauge\n");
        for kind in OpKind::ALL {
            let slot = self.exemplars[kind.index()].lock_safe();
            if slot.present {
                out.push_str(&format!(
                    "hopaas_slow_trace_seconds{{api=\"{}\",trace_id=\"{}\"}} {}\n",
                    kind.name(),
                    slot.id.as_str(),
                    slot.seconds
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(capacity: usize, sample: f64, slow_ms: u64) -> Tracer {
        Tracer::new(TracerConfig { capacity, sample, slow_ms, log_json: false })
    }

    #[test]
    fn req_id_parse_sanitizes_and_truncates() {
        assert_eq!(ReqId::parse("abc-123").unwrap().as_str(), "abc-123");
        assert_eq!(ReqId::parse("  a b\"c\n ").unwrap().as_str(), "abc");
        assert!(ReqId::parse("\"\n ").is_none());
        assert!(ReqId::parse("").is_none());
        let long = "x".repeat(200);
        assert_eq!(ReqId::parse(&long).unwrap().as_str().len(), ID_CAP);
    }

    #[test]
    fn req_id_generate_is_unique_per_counter() {
        let a = ReqId::generate(1);
        let b = ReqId::generate(2);
        assert_ne!(a.as_str(), b.as_str());
        assert!(a.as_str().starts_with("req-"));
        assert_eq!(a.as_str().len(), 20);
    }

    #[test]
    fn tag_truncates_on_char_boundary() {
        let t = Tag::new("héllo-wörld-with-a-long-tail");
        assert!(t.as_str().len() <= TAG_CAP);
        assert!(t.as_str().starts_with("héllo"));
    }

    #[test]
    fn classify_maps_mutations_and_reads() {
        assert_eq!(classify("POST", "/api/studies/3/ask"), OpKind::Ask);
        assert_eq!(classify("POST", "/api/studies/3/trials/4/tell"), OpKind::Tell);
        assert_eq!(
            classify("POST", "/api/studies/3/trials/4/should_prune"),
            OpKind::Prune
        );
        assert_eq!(classify("POST", "/api/studies/3/trials/4/fail"), OpKind::Fail);
        // Token-suffixed routes: the verb segment is mid-path.
        assert_eq!(classify("POST", "/api/ask/SECRET-TOKEN"), OpKind::Ask);
        assert_eq!(classify("POST", "/api/should_prune/tok"), OpKind::Prune);
        assert_eq!(classify("GET", "/api/studies?limit=5"), OpKind::Read);
        assert_eq!(classify("POST", "/api/studies"), OpKind::Other);
    }

    #[test]
    fn span_records_stages_and_attribution_through_tls() {
        let t = tracer(16, 1.0, 0);
        let span = t.begin(Some("client-id-1"), OpKind::Ask);
        install(span);
        assert!(active());
        assert_eq!(current_id().unwrap().as_str(), "client-id-1");
        stage_us(Stage::Admission, 5);
        stage_us(Stage::ShardLock, 7);
        stage_us(Stage::WalFsync, 1200);
        set_study(42);
        set_tenant("atlas");
        set_worker("w-1");
        set_site("cnaf");
        let span = take().unwrap();
        assert!(!active());
        t.finish(span, 200);
        let v = t.get("client-id-1").expect("trace retained");
        assert_eq!(v.get("kind").as_str(), Some("ask"));
        assert_eq!(v.get("study").as_u64(), Some(42));
        assert_eq!(v.get("tenant").as_str(), Some("atlas"));
        assert_eq!(v.get("worker").as_str(), Some("w-1"));
        assert_eq!(v.get("site").as_str(), Some("cnaf"));
        let stages = match v.get("stages") {
            Value::Arr(a) => a,
            other => panic!("stages not an array: {other:?}"),
        };
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[0].get("stage").as_str(), Some("admission"));
        assert_eq!(stages[2].get("stage").as_str(), Some("wal_fsync"));
        assert_eq!(stages[2].get("dur_us").as_u64(), Some(1200));
    }

    #[test]
    fn ring_overwrites_oldest_and_recent_is_newest_first() {
        let t = tracer(4, 1.0, 0);
        for i in 0..10 {
            let span = t.begin(Some(&format!("id-{i}")), OpKind::Read);
            t.finish(span, 200);
        }
        let recent = match t.recent(10, None, None) {
            Value::Arr(a) => a,
            other => panic!("not an array: {other:?}"),
        };
        assert_eq!(recent.len(), 4, "capacity bounds retention");
        assert_eq!(recent[0].get("id").as_str(), Some("id-9"));
        assert!(t.get("id-0").is_none(), "oldest evicted");
        assert!(t.get("id-9").is_some());
    }

    #[test]
    fn sampling_skips_but_slow_is_always_retained() {
        let t = tracer(64, 0.0, 1); // sample nothing; slow ≥ 1ms
        let fast = t.begin(Some("fast-1"), OpKind::Read);
        t.finish(fast, 200);
        assert!(t.get("fast-1").is_none(), "unsampled fast op dropped");
        let slow = t.begin(Some("slow-1"), OpKind::Ask);
        std::thread::sleep(Duration::from_millis(5));
        t.finish(slow, 200);
        let v = t.get("slow-1").expect("slow op retained despite sample=0");
        assert_eq!(v.get("slow").as_bool(), Some(true));
        assert_eq!(t.stats_json().get("slow").as_u64(), Some(1));
    }

    #[test]
    fn recent_filters_by_kind_and_study() {
        let t = tracer(32, 1.0, 0);
        let mut span = t.begin(Some("ask-a"), OpKind::Ask);
        span.study = 7;
        t.finish(span, 200);
        let span = t.begin(Some("read-b"), OpKind::Read);
        t.finish(span, 200);
        let asks = match t.recent(10, Some(OpKind::Ask), None) {
            Value::Arr(a) => a,
            other => panic!("not an array: {other:?}"),
        };
        assert_eq!(asks.len(), 1);
        assert_eq!(asks[0].get("id").as_str(), Some("ask-a"));
        let study7 = match t.recent(10, None, Some(7)) {
            Value::Arr(a) => a,
            other => panic!("not an array: {other:?}"),
        };
        assert_eq!(study7.len(), 1);
        let none = match t.recent(10, Some(OpKind::Tell), None) {
            Value::Arr(a) => a,
            other => panic!("not an array: {other:?}"),
        };
        assert!(none.is_empty());
    }

    #[test]
    fn disabled_tracer_retains_nothing() {
        let t = tracer(0, 1.0, 0);
        assert!(!t.enabled());
        let span = t.begin(Some("x"), OpKind::Ask);
        t.finish(span, 200);
        assert!(t.get("x").is_none());
    }

    #[test]
    fn exemplars_render_for_slowest_request() {
        let t = tracer(8, 1.0, 0);
        let span = t.begin(Some("slowest-ask"), OpKind::Ask);
        std::thread::sleep(Duration::from_millis(2));
        t.finish(span, 200);
        let span = t.begin(Some("fast-ask"), OpKind::Ask);
        t.finish(span, 200);
        let mut out = String::new();
        t.render_exemplars(&mut out);
        assert!(out.contains("# TYPE hopaas_slow_trace_seconds gauge"));
        assert!(out.contains("api=\"ask\""));
        assert!(out.contains("trace_id=\"slowest-ask\""));
        assert!(!out.contains("fast-ask"), "only the slowest is exported");
    }
}

//! Quota policy: who may hold how many concurrent trial slots, resolved
//! per admission.
//!
//! PR 3 shipped two uniform knobs (`--site-quota`, `--study-quota`).
//! A shared instance coordinating campaigns from private boxes, INFN
//! Cloud and CINECA needs more than that (paper §4): MARCONI 100 can
//! absorb ten times the concurrency of a private box, and one user's
//! runaway campaign must not eat another user's admission budget. The
//! policy table therefore resolves, per admission:
//!
//! * **site quota** — a per-site override map (`site → quota`) over the
//!   uniform default; `0` means unlimited for that site;
//! * **tenant quota** — a per-tenant cap keyed by the identity behind
//!   the auth token presented on the ask (the token's `user` claim),
//!   with a per-tenant override map over a uniform default;
//! * **study quota** — unchanged from PR 3;
//! * **fairness horizon** — how long a denied study's *waiting* mark
//!   keeps claiming a fair share of a site. Seconds, not hours: an
//!   abandoned campaign must stop deflating everyone else's share as
//!   soon as it stops asking (see `scheduler`);
//! * **site affinity** — when enabled, requeued (preempted) trials are
//!   preferentially handed to workers on healthier sites: a worker on a
//!   site with an above-average loss rate is served a *fresh* trial
//!   instead of the queue head until the head has waited a full
//!   fairness horizon. Trial identity (id/number/params) is never
//!   touched, so suggestion streams stay byte-identical whether
//!   affinity is on or off.
//!
//! Policy denials map to HTTP 429 with the denied scope named in the
//! detail (`site '…'`, `tenant '…'`, `study quota`), so clients and
//! dashboards can attribute back-pressure.

use crate::json::Value;
use std::collections::HashMap;

/// The resolved admission policy. Part of [`super::FleetConfig`].
#[derive(Clone, Debug)]
pub struct QuotaPolicy {
    /// Default max concurrently leased trials per site (0 = unlimited).
    pub site_quota: u32,
    /// Per-site overrides (`site → quota`); an explicit 0 lifts the
    /// default for that site.
    pub site_quotas: HashMap<String, u32>,
    /// Max concurrently leased trials per study (0 = unlimited).
    pub study_quota: u32,
    /// Default max concurrently leased trials per tenant (0 = unlimited).
    pub tenant_quota: u32,
    /// Per-tenant overrides (`tenant → quota`).
    pub tenant_quotas: HashMap<String, u32>,
    /// Waiting-mark lifetime for fair-share admission, seconds. Also the
    /// grace after which site affinity stops deferring a queued trial.
    pub fairness_horizon: f64,
    /// Prefer healthier sites when handing out requeued trials.
    pub site_affinity: bool,
}

impl Default for QuotaPolicy {
    fn default() -> Self {
        QuotaPolicy {
            site_quota: 0,
            site_quotas: HashMap::new(),
            study_quota: 0,
            tenant_quota: 0,
            tenant_quotas: HashMap::new(),
            fairness_horizon: 30.0,
            site_affinity: false,
        }
    }
}

impl QuotaPolicy {
    /// Effective quota for `site`: override first, default otherwise.
    pub fn site_quota_for(&self, site: &str) -> u32 {
        self.site_quotas.get(site).copied().unwrap_or(self.site_quota)
    }

    /// Effective quota for `tenant`: override first, default otherwise.
    pub fn tenant_quota_for(&self, tenant: &str) -> u32 {
        self.tenant_quotas
            .get(tenant)
            .copied()
            .unwrap_or(self.tenant_quota)
    }

    /// Parse a `key=value,key=value` CLI override list (`--site-quota-map
    /// marconi100=64,private=2`). Malformed entries are reported, not
    /// silently dropped — a typo'd quota map is a policy hole.
    pub fn parse_map(spec: &str) -> Result<HashMap<String, u32>, String> {
        let mut out = HashMap::new();
        for pair in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("quota map entry '{pair}' is not key=value"))?;
            let n: u32 = v
                .parse()
                .map_err(|_| format!("quota map entry '{pair}': '{v}' is not a u32"))?;
            out.insert(key.trim().to_string(), n);
        }
        Ok(out)
    }

    /// Read an override map from a JSON config object (`{"site": 4}`).
    /// Malformed entries error, like [`QuotaPolicy::parse_map`] does on
    /// the CLI — a dropped override would silently fall back to the
    /// default quota (a policy hole, not a recoverable typo).
    pub fn map_from_json(v: &Value) -> Result<HashMap<String, u32>, String> {
        let mut out = HashMap::new();
        match v {
            Value::Obj(o) => {
                for (k, val) in o.iter() {
                    match val.as_u64() {
                        Some(n) if n <= u32::MAX as u64 => {
                            out.insert(k.to_string(), n as u32);
                        }
                        _ => {
                            return Err(format!("quota map entry '{k}': {val} is not a u32"))
                        }
                    }
                }
            }
            Value::Null => {}
            other => return Err(format!("quota map must be an object, got {other}")),
        }
        Ok(out)
    }

    /// Policy block for `/api/stats` (operators audit what is enforced).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("site_quota", self.site_quota)
            .set("site_overrides", map_json(&self.site_quotas))
            .set("study_quota", self.study_quota)
            .set("tenant_quota", self.tenant_quota)
            .set("tenant_overrides", map_json(&self.tenant_quotas))
            .set("fairness_horizon", self.fairness_horizon)
            .set("site_affinity", self.site_affinity);
        Value::Obj(o)
    }
}

fn map_json(m: &HashMap<String, u32>) -> Value {
    let mut keys: Vec<&String> = m.keys().collect();
    keys.sort();
    let mut o = Value::obj();
    for k in keys {
        o.set(k.as_str(), m[k]);
    }
    Value::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_beats_default() {
        let mut p = QuotaPolicy { site_quota: 4, tenant_quota: 2, ..Default::default() };
        p.site_quotas.insert("marconi100".into(), 64);
        p.site_quotas.insert("private".into(), 0);
        p.tenant_quotas.insert("alice".into(), 8);
        assert_eq!(p.site_quota_for("marconi100"), 64);
        assert_eq!(p.site_quota_for("infn-cloud"), 4, "default applies");
        assert_eq!(p.site_quota_for("private"), 0, "explicit 0 lifts the cap");
        assert_eq!(p.tenant_quota_for("alice"), 8);
        assert_eq!(p.tenant_quota_for("bob"), 2);
    }

    #[test]
    fn parse_map_forms() {
        let m = QuotaPolicy::parse_map("a=1,b=2").unwrap();
        assert_eq!(m.get("a"), Some(&1));
        assert_eq!(m.get("b"), Some(&2));
        assert!(QuotaPolicy::parse_map("").unwrap().is_empty());
        assert!(QuotaPolicy::parse_map("a").is_err(), "missing =");
        assert!(QuotaPolicy::parse_map("a=x").is_err(), "non-numeric");
    }

    #[test]
    fn map_from_json_object() {
        let v = crate::json::parse(r#"{"gpu": 4, "cpu": 8}"#).unwrap();
        let m = QuotaPolicy::map_from_json(&v).unwrap();
        assert_eq!(m.get("gpu"), Some(&4));
        assert_eq!(m.get("cpu"), Some(&8));
        assert!(QuotaPolicy::map_from_json(&Value::Null).unwrap().is_empty());
        // Malformed entries are errors, not silent fallbacks to the
        // default quota.
        let bad = crate::json::parse(r#"{"gpu": "4"}"#).unwrap();
        assert!(QuotaPolicy::map_from_json(&bad).is_err(), "string value");
        let bad = crate::json::parse(r#"{"gpu": -1}"#).unwrap();
        assert!(QuotaPolicy::map_from_json(&bad).is_err(), "negative value");
        assert!(QuotaPolicy::map_from_json(&Value::Num(3.0)).is_err(), "non-object");
    }

    #[test]
    fn stats_json_shape() {
        let mut p = QuotaPolicy { site_quota: 4, ..Default::default() };
        p.site_quotas.insert("hpc".into(), 64);
        let j = p.to_json();
        assert_eq!(j.get("site_quota").as_u64(), Some(4));
        assert_eq!(j.get("site_overrides").get("hpc").as_u64(), Some(64));
        assert_eq!(j.get("site_affinity").as_bool(), Some(false));
    }
}

//! Quota policy: who may hold how many concurrent trial slots, resolved
//! per admission.
//!
//! PR 3 shipped two uniform knobs (`--site-quota`, `--study-quota`).
//! A shared instance coordinating campaigns from private boxes, INFN
//! Cloud and CINECA needs more than that (paper §4): MARCONI 100 can
//! absorb ten times the concurrency of a private box, and one user's
//! runaway campaign must not eat another user's admission budget. The
//! policy table therefore resolves, per admission:
//!
//! * **site quota** — a per-site override map (`site → quota`) over the
//!   uniform default; `0` means unlimited for that site;
//! * **tenant quota** — a per-tenant cap keyed by the identity behind
//!   the auth token presented on the ask (the token's `user` claim),
//!   with a per-tenant override map over a uniform default;
//! * **study quota** — unchanged from PR 3;
//! * **tenant ask rate** — a sliding-window cap on *worker-less*
//!   (legacy) asks per tenant. Lease quotas bound only asks that hold
//!   scheduler slots; a legacy client that never names a worker used to
//!   bypass tenant admission entirely. The [`TenantRateLedger`] closes
//!   that hole: past the rate, worker-less asks 429 with the tenant
//!   named, exactly like lease-quota denials;
//! * **fairness horizon** — how long a denied study's *waiting* mark
//!   keeps claiming a fair share of a site. Seconds, not hours: an
//!   abandoned campaign must stop deflating everyone else's share as
//!   soon as it stops asking (see `scheduler`);
//! * **site affinity** — when enabled, requeued (preempted) trials are
//!   preferentially handed to workers on healthier sites: a worker on a
//!   site with an above-average loss rate is served a *fresh* trial
//!   instead of the queue head until the head has waited a full
//!   fairness horizon. Trial identity (id/number/params) is never
//!   touched, so suggestion streams stay byte-identical whether
//!   affinity is on or off.
//!
//! Policy denials map to HTTP 429 with the denied scope named in the
//! detail (`site '…'`, `tenant '…'`, `study quota`), so clients and
//! dashboards can attribute back-pressure.

use crate::coordinator::engine::ApiError;
use crate::json::Value;
use std::collections::{HashMap, VecDeque};

/// The resolved admission policy. Part of [`super::FleetConfig`].
#[derive(Clone, Debug)]
pub struct QuotaPolicy {
    /// Default max concurrently leased trials per site (0 = unlimited).
    pub site_quota: u32,
    /// Per-site overrides (`site → quota`); an explicit 0 lifts the
    /// default for that site.
    pub site_quotas: HashMap<String, u32>,
    /// Max concurrently leased trials per study (0 = unlimited).
    pub study_quota: u32,
    /// Default max concurrently leased trials per tenant (0 = unlimited).
    pub tenant_quota: u32,
    /// Per-tenant overrides (`tenant → quota`).
    pub tenant_quotas: HashMap<String, u32>,
    /// Max worker-less asks per tenant within the sliding
    /// `tenant_ask_window` (0 = unlimited). Bounds legacy clients that
    /// never hold a lease and therefore never hit the lease quotas.
    pub tenant_ask_rate: u32,
    /// Sliding window of the worker-less ask-rate ledger, seconds.
    pub tenant_ask_window: f64,
    /// Waiting-mark lifetime for fair-share admission, seconds. Also the
    /// grace after which site affinity stops deferring a queued trial.
    pub fairness_horizon: f64,
    /// Prefer healthier sites when handing out requeued trials.
    pub site_affinity: bool,
}

impl Default for QuotaPolicy {
    fn default() -> Self {
        QuotaPolicy {
            site_quota: 0,
            site_quotas: HashMap::new(),
            study_quota: 0,
            tenant_quota: 0,
            tenant_quotas: HashMap::new(),
            tenant_ask_rate: 0,
            tenant_ask_window: 60.0,
            fairness_horizon: 30.0,
            site_affinity: false,
        }
    }
}

impl QuotaPolicy {
    /// Effective quota for `site`: override first, default otherwise.
    pub fn site_quota_for(&self, site: &str) -> u32 {
        self.site_quotas.get(site).copied().unwrap_or(self.site_quota)
    }

    /// Effective quota for `tenant`: override first, default otherwise.
    pub fn tenant_quota_for(&self, tenant: &str) -> u32 {
        self.tenant_quotas
            .get(tenant)
            .copied()
            .unwrap_or(self.tenant_quota)
    }

    /// Parse a `key=value,key=value` CLI override list (`--site-quota-map
    /// marconi100=64,private=2`). Malformed entries are reported, not
    /// silently dropped — a typo'd quota map is a policy hole.
    pub fn parse_map(spec: &str) -> Result<HashMap<String, u32>, String> {
        let mut out = HashMap::new();
        for pair in spec.split(',').filter(|p| !p.is_empty()) {
            let (key, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("quota map entry '{pair}' is not key=value"))?;
            let n: u32 = v
                .parse()
                .map_err(|_| format!("quota map entry '{pair}': '{v}' is not a u32"))?;
            out.insert(key.trim().to_string(), n);
        }
        Ok(out)
    }

    /// Read an override map from a JSON config object (`{"site": 4}`).
    /// Malformed entries error, like [`QuotaPolicy::parse_map`] does on
    /// the CLI — a dropped override would silently fall back to the
    /// default quota (a policy hole, not a recoverable typo).
    pub fn map_from_json(v: &Value) -> Result<HashMap<String, u32>, String> {
        let mut out = HashMap::new();
        match v {
            Value::Obj(o) => {
                for (k, val) in o.iter() {
                    match val.as_u64() {
                        Some(n) if n <= u32::MAX as u64 => {
                            out.insert(k.to_string(), n as u32);
                        }
                        _ => {
                            return Err(format!("quota map entry '{k}': {val} is not a u32"))
                        }
                    }
                }
            }
            Value::Null => {}
            other => return Err(format!("quota map must be an object, got {other}")),
        }
        Ok(out)
    }

    /// Policy block for `/api/stats` (operators audit what is enforced).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("site_quota", self.site_quota)
            .set("site_overrides", map_json(&self.site_quotas))
            .set("study_quota", self.study_quota)
            .set("tenant_quota", self.tenant_quota)
            .set("tenant_overrides", map_json(&self.tenant_quotas))
            .set("tenant_ask_rate", self.tenant_ask_rate)
            .set("tenant_ask_window", self.tenant_ask_window)
            .set("fairness_horizon", self.fairness_horizon)
            .set("site_affinity", self.site_affinity);
        Value::Obj(o)
    }
}

/// Sliding-window per-tenant ask-rate ledger for worker-less asks.
///
/// A tenant's recent worker-less asks are kept as a deque of
/// timestamps, pruned to the window on every touch, so each entry is
/// bounded by the rate limit and the map holds only tenants seen
/// within the window (plus whatever [`TenantRateLedger::gc`] hasn't
/// swept yet — tenant names are client-influenced strings and must not
/// accumulate forever).
#[derive(Default)]
pub struct TenantRateLedger {
    asks: HashMap<String, VecDeque<f64>>,
}

impl TenantRateLedger {
    /// Admit (and record) one worker-less ask by `tenant` at `now`, or
    /// deny with the tenant named in the 429 detail. The `tenant '`
    /// prefix is what [`super::scheduler::is_tenant_denial`] classifies
    /// on — keep the two in sync.
    pub fn note_ask(
        &mut self,
        tenant: &str,
        now: f64,
        limit: u32,
        window: f64,
    ) -> Result<(), ApiError> {
        if limit == 0 {
            return Ok(());
        }
        let window = window.max(1e-9);
        let q = self.asks.entry(tenant.to_string()).or_default();
        while q.front().is_some_and(|&t| now - t >= window) {
            q.pop_front();
        }
        if q.len() >= limit as usize {
            return Err(ApiError::Quota(format!(
                "tenant '{tenant}' ask rate reached ({limit} asks per {window}s)"
            )));
        }
        q.push_back(now);
        Ok(())
    }

    /// Asks by `tenant` still inside the window (tests/diagnostics).
    pub fn recent(&self, tenant: &str, now: f64, window: f64) -> usize {
        self.asks
            .get(tenant)
            .map(|q| q.iter().filter(|&&t| now - t < window.max(1e-9)).count())
            .unwrap_or(0)
    }

    /// Drop tenants whose whole window has expired. Returns how many
    /// entries were evicted.
    pub fn gc(&mut self, now: f64, window: f64) -> usize {
        let window = window.max(1e-9);
        let before = self.asks.len();
        self.asks.retain(|_, q| {
            while q.front().is_some_and(|&t| now - t >= window) {
                q.pop_front();
            }
            !q.is_empty()
        });
        before - self.asks.len()
    }
}

fn map_json(m: &HashMap<String, u32>) -> Value {
    let mut keys: Vec<&String> = m.keys().collect();
    keys.sort();
    let mut o = Value::obj();
    for k in keys {
        o.set(k.as_str(), m[k]);
    }
    Value::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_beats_default() {
        let mut p = QuotaPolicy { site_quota: 4, tenant_quota: 2, ..Default::default() };
        p.site_quotas.insert("marconi100".into(), 64);
        p.site_quotas.insert("private".into(), 0);
        p.tenant_quotas.insert("alice".into(), 8);
        assert_eq!(p.site_quota_for("marconi100"), 64);
        assert_eq!(p.site_quota_for("infn-cloud"), 4, "default applies");
        assert_eq!(p.site_quota_for("private"), 0, "explicit 0 lifts the cap");
        assert_eq!(p.tenant_quota_for("alice"), 8);
        assert_eq!(p.tenant_quota_for("bob"), 2);
    }

    #[test]
    fn parse_map_forms() {
        let m = QuotaPolicy::parse_map("a=1,b=2").unwrap();
        assert_eq!(m.get("a"), Some(&1));
        assert_eq!(m.get("b"), Some(&2));
        assert!(QuotaPolicy::parse_map("").unwrap().is_empty());
        assert!(QuotaPolicy::parse_map("a").is_err(), "missing =");
        assert!(QuotaPolicy::parse_map("a=x").is_err(), "non-numeric");
    }

    #[test]
    fn map_from_json_object() {
        let v = crate::json::parse(r#"{"gpu": 4, "cpu": 8}"#).unwrap();
        let m = QuotaPolicy::map_from_json(&v).unwrap();
        assert_eq!(m.get("gpu"), Some(&4));
        assert_eq!(m.get("cpu"), Some(&8));
        assert!(QuotaPolicy::map_from_json(&Value::Null).unwrap().is_empty());
        // Malformed entries are errors, not silent fallbacks to the
        // default quota.
        let bad = crate::json::parse(r#"{"gpu": "4"}"#).unwrap();
        assert!(QuotaPolicy::map_from_json(&bad).is_err(), "string value");
        let bad = crate::json::parse(r#"{"gpu": -1}"#).unwrap();
        assert!(QuotaPolicy::map_from_json(&bad).is_err(), "negative value");
        assert!(QuotaPolicy::map_from_json(&Value::Num(3.0)).is_err(), "non-object");
    }

    #[test]
    fn stats_json_shape() {
        let mut p = QuotaPolicy { site_quota: 4, tenant_ask_rate: 10, ..Default::default() };
        p.site_quotas.insert("hpc".into(), 64);
        let j = p.to_json();
        assert_eq!(j.get("site_quota").as_u64(), Some(4));
        assert_eq!(j.get("site_overrides").get("hpc").as_u64(), Some(64));
        assert_eq!(j.get("site_affinity").as_bool(), Some(false));
        assert_eq!(j.get("tenant_ask_rate").as_u64(), Some(10));
        assert_eq!(j.get("tenant_ask_window").as_f64(), Some(60.0));
    }

    #[test]
    fn ask_rate_window_slides() {
        let mut l = TenantRateLedger::default();
        // Limit 2 per 10 s.
        assert!(l.note_ask("alice", 0.0, 2, 10.0).is_ok());
        assert!(l.note_ask("alice", 1.0, 2, 10.0).is_ok());
        let err = l.note_ask("alice", 2.0, 2, 10.0).unwrap_err();
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        assert!(super::super::scheduler::is_tenant_denial(&err), "classified as tenant 429");
        // Other tenants have their own window.
        assert!(l.note_ask("bob", 2.0, 2, 10.0).is_ok());
        // The window slides: at t=10 the t=0 ask has aged out…
        assert!(l.note_ask("alice", 10.0, 2, 10.0).is_ok());
        // …but the t=1 and t=10 asks still fill the window at t=10.5.
        assert!(l.note_ask("alice", 10.5, 2, 10.0).is_err());
        assert_eq!(l.recent("alice", 10.5, 10.0), 2);
        // Limit 0 disables the ledger entirely (nothing recorded).
        let mut off = TenantRateLedger::default();
        for i in 0..50 {
            assert!(off.note_ask("alice", i as f64, 0, 10.0).is_ok());
        }
        assert_eq!(off.recent("alice", 50.0, 10.0), 0);
    }

    #[test]
    fn ask_rate_ledger_gc_drops_expired_tenants() {
        let mut l = TenantRateLedger::default();
        l.note_ask("alice", 0.0, 4, 10.0).unwrap();
        l.note_ask("bob", 5.0, 4, 10.0).unwrap();
        assert_eq!(l.gc(9.0, 10.0), 0, "both windows still live");
        assert_eq!(l.gc(12.0, 10.0), 1, "alice aged out");
        assert_eq!(l.recent("bob", 12.0, 10.0), 1);
        assert_eq!(l.gc(20.0, 10.0), 1, "bob aged out");
        assert_eq!(l.recent("alice", 20.0, 10.0), 0);
    }
}

//! Lease table: which worker holds which trial, and the requeue queues
//! that re-home trials whose worker vanished.
//!
//! A *lease* binds a running trial to a worker; it lives exactly as
//! long as the worker's heartbeat lease does (there is no per-trial
//! deadline — renewing the worker renews all of its trials at once).
//! When a worker is lost, each of its leased trials moves to its
//! study's *requeue queue*: a FIFO of fully-formed trials (id, number
//! and parameters already fixed) waiting for the next eligible `ask` of
//! the same study. Handing out a requeued trial does not touch the
//! study's trial-number reservation or sampler history, which is why
//! preemption cannot perturb the deterministic suggestion stream.

use crate::json::Value;
use std::collections::{HashMap, HashSet, VecDeque};

/// One live lease. Carries the admission keys (site, tenant) the
/// scheduler counted when the slot was reserved, so the release path
/// returns exactly the slot that was taken — independent of later
/// registry mutations (a worker GC must never corrupt quota headroom).
#[derive(Clone, Debug)]
pub struct LeaseInfo {
    pub worker: u64,
    pub study_key: String,
    /// Site of the worker at bind time (the quota key).
    pub site: String,
    /// Tenant behind the ask's auth token, if any (the quota key).
    pub tenant: Option<String>,
    pub bound_at: f64,
}

/// Lease table + per-study requeue queues. Part of `FleetState`.
#[derive(Default)]
pub struct LeaseTable {
    /// trial id → holder.
    leases: HashMap<u64, LeaseInfo>,
    /// study key → trials waiting for a new worker (FIFO).
    queues: HashMap<String, VecDeque<u64>>,
    /// Every trial in some queue — O(1) membership so a mass
    /// preemption (thousands of requeues under the fleet lock) does
    /// not degrade into per-push linear queue scans.
    queued: HashSet<u64>,
    /// trial id → when it entered its queue. Affinity input only (how
    /// long has the head waited?); engine-relative seconds, so it is
    /// not persisted — recovered queue entries read as "waited forever"
    /// and are immediately eligible for any site.
    queued_at: HashMap<u64, f64>,
    /// trial id → times it has been requeued (budget tracking).
    requeues: HashMap<u64, u32>,
}

impl LeaseTable {
    pub fn bind(
        &mut self,
        trial_id: u64,
        worker: u64,
        study_key: &str,
        site: &str,
        tenant: Option<&str>,
        now: f64,
    ) {
        self.leases.insert(
            trial_id,
            LeaseInfo {
                worker,
                study_key: study_key.to_string(),
                site: site.to_string(),
                tenant: tenant.map(str::to_string),
                bound_at: now,
            },
        );
    }

    pub fn get(&self, trial_id: u64) -> Option<&LeaseInfo> {
        self.leases.get(&trial_id)
    }

    pub fn is_leased(&self, trial_id: u64) -> bool {
        self.leases.contains_key(&trial_id)
    }

    pub fn release(&mut self, trial_id: u64) -> Option<LeaseInfo> {
        self.leases.remove(&trial_id)
    }

    pub fn len(&self) -> usize {
        self.leases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leases.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&u64, &LeaseInfo)> {
        self.leases.iter()
    }

    /// Trial ids of every live lease (reap-skip set).
    pub fn leased_ids(&self) -> Vec<u64> {
        self.leases.keys().copied().collect()
    }

    /// Trial ids currently waiting in a requeue queue (reap-skip set:
    /// a queued trial is fleet-owned, not abandoned).
    pub fn queued_ids(&self) -> Vec<u64> {
        self.queued.iter().copied().collect()
    }

    /// Every trial the table knows about — leased or queued — with its
    /// study key (scrub input).
    pub fn all_tracked(&self) -> Vec<(u64, String)> {
        let mut out: Vec<(u64, String)> = self
            .leases
            .iter()
            .map(|(tid, info)| (*tid, info.study_key.clone()))
            .collect();
        for (key, q) in &self.queues {
            for tid in q {
                out.push((*tid, key.clone()));
            }
        }
        out
    }

    // --- requeue queues --------------------------------------------------

    /// Append to the study's requeue queue and charge the budget. Never
    /// double-queues a trial (replay idempotence).
    pub fn push_back(&mut self, study_key: &str, trial_id: u64, now: f64) {
        if self.queued.insert(trial_id) {
            self.queues.entry(study_key.to_string()).or_default().push_back(trial_id);
            self.queued_at.entry(trial_id).or_insert(now);
            *self.requeues.entry(trial_id).or_insert(0) += 1;
        }
    }

    /// Return a popped trial to the head of its queue (a failed handout
    /// must not lose it, nor re-charge its budget — nor reset its wait
    /// clock). The id may still be in `queued` (pop leaves it there), so
    /// the queue re-insert is gated on the queue itself — O(n), but only
    /// on this error path.
    pub fn push_front(&mut self, study_key: &str, trial_id: u64, now: f64) {
        self.queued.insert(trial_id);
        self.queued_at.entry(trial_id).or_insert(now);
        let q = self.queues.entry(study_key.to_string()).or_default();
        if !q.contains(&trial_id) {
            q.push_front(trial_id);
        }
    }

    /// Pop the next waiting trial. Deliberately leaves the id in
    /// `queued`: between this pop and the eventual bind the trial is
    /// *in flight*, and the reaper's fleet-owned snapshot must keep
    /// covering it or it could be failed out from under the handout.
    /// [`LeaseTable::finish_handout`] (via bind) or a forget clears it.
    pub fn pop_front(&mut self, study_key: &str) -> Option<u64> {
        self.queues.get_mut(study_key)?.pop_front()
    }

    /// How long the head of `study_key`'s queue has been waiting, if
    /// any trial is queued. The affinity preference defers handouts to
    /// unhealthy sites only while this is under the fairness horizon.
    pub fn head_wait(&self, study_key: &str, now: f64) -> Option<f64> {
        let head = *self.queues.get(study_key)?.front()?;
        Some(now - self.queued_at.get(&head).copied().unwrap_or(0.0))
    }

    /// The popped trial reached its new lease: drop the in-flight mark.
    pub fn finish_handout(&mut self, trial_id: u64) {
        self.queued.remove(&trial_id);
        self.queued_at.remove(&trial_id);
    }

    pub fn remove_from_queue(&mut self, study_key: &str, trial_id: u64) {
        if self.queued.remove(&trial_id) {
            self.queued_at.remove(&trial_id);
            if let Some(q) = self.queues.get_mut(study_key) {
                q.retain(|&t| t != trial_id);
            }
        }
    }

    pub fn is_queued(&self, trial_id: u64) -> bool {
        self.queued.contains(&trial_id)
    }

    pub fn queue_depth(&self) -> usize {
        self.queued.len()
    }

    pub fn requeues(&self, trial_id: u64) -> u32 {
        self.requeues.get(&trial_id).copied().unwrap_or(0)
    }

    pub fn clear_requeues(&mut self, trial_id: u64) {
        self.requeues.remove(&trial_id);
    }

    // --- segment (de)serialization --------------------------------------

    /// Backfill the admission site of a lease loaded from an old-format
    /// snapshot (pre-policy segments carried no `site` field).
    pub fn set_site(&mut self, trial_id: u64, site: &str) {
        if let Some(info) = self.leases.get_mut(&trial_id) {
            info.site = site.to_string();
        }
    }

    pub fn leases_json(&self) -> Value {
        let mut ids: Vec<u64> = self.leases.keys().copied().collect();
        ids.sort_unstable();
        Value::Arr(
            ids.iter()
                .map(|tid| {
                    let info = &self.leases[tid];
                    let mut o = Value::obj();
                    o.set("trial", *tid)
                        .set("worker", info.worker)
                        .set("study", info.study_key.as_str())
                        .set("site", info.site.as_str())
                        .set("tenant", info.tenant.clone())
                        .set("at", info.bound_at);
                    Value::Obj(o)
                })
                .collect(),
        )
    }

    pub fn queues_json(&self) -> Value {
        let mut keys: Vec<&String> = self.queues.keys().collect();
        keys.sort();
        Value::Arr(
            keys.iter()
                .filter(|k| !self.queues[**k].is_empty())
                .map(|k| {
                    let mut o = Value::obj();
                    o.set("study", k.as_str()).set(
                        "trials",
                        Value::Arr(self.queues[*k].iter().map(|&t| Value::from(t)).collect()),
                    );
                    Value::Obj(o)
                })
                .collect(),
        )
    }

    pub fn requeue_counts_json(&self) -> Value {
        let mut ids: Vec<u64> = self.requeues.keys().copied().collect();
        ids.sort_unstable();
        Value::Arr(
            ids.iter()
                .map(|tid| {
                    Value::Arr(vec![Value::from(*tid), Value::from(self.requeues[tid])])
                })
                .collect(),
        )
    }

    pub fn load_json(&mut self, leases: &Value, queues: &Value, counts: &Value) {
        self.leases.clear();
        self.queues.clear();
        self.queued.clear();
        self.queued_at.clear();
        self.requeues.clear();
        for lv in leases.as_arr().unwrap_or(&[]) {
            if let (Some(tid), Some(wid), Some(study)) = (
                lv.get("trial").as_u64(),
                lv.get("worker").as_u64(),
                lv.get("study").as_str(),
            ) {
                self.bind(
                    tid,
                    wid,
                    study,
                    lv.get("site").as_str().unwrap_or(""),
                    lv.get("tenant").as_str(),
                    lv.get("at").as_f64().unwrap_or(0.0),
                );
            }
        }
        for qv in queues.as_arr().unwrap_or(&[]) {
            let Some(study) = qv.get("study").as_str() else { continue };
            for tv in qv.get("trials").as_arr().unwrap_or(&[]) {
                if let Some(tid) = tv.as_u64() {
                    // Budgets come from `counts` below, not push_back.
                    // Wait clocks restart at "forever ago" (time bases
                    // don't survive a restart): recovered queue heads
                    // are never affinity-deferred.
                    if self.queued.insert(tid) {
                        self.queues.entry(study.to_string()).or_default().push_back(tid);
                        self.queued_at.insert(tid, f64::NEG_INFINITY);
                    }
                }
            }
        }
        for cv in counts.as_arr().unwrap_or(&[]) {
            if let (Some(tid), Some(n)) = (cv.at(0).as_u64(), cv.at(1).as_u64()) {
                self.requeues.insert(tid, n as u32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_fifo_and_budget() {
        let mut t = LeaseTable::default();
        t.push_back("s", 1, 0.0);
        t.push_back("s", 2, 1.0);
        t.push_back("s", 1, 2.0); // double-queue ignored, budget not re-charged
        assert_eq!(t.queue_depth(), 2);
        assert_eq!(t.requeues(1), 1);
        assert_eq!(t.head_wait("s", 5.0), Some(5.0), "head queued at t=0");
        assert_eq!(t.pop_front("s"), Some(1));
        t.push_front("s", 1, 9.0); // failed handout goes back to the head
        assert_eq!(t.requeues(1), 1, "push_front never charges the budget");
        assert_eq!(t.head_wait("s", 9.0), Some(9.0), "wait clock not reset");
        assert_eq!(t.pop_front("s"), Some(1));
        assert_eq!(t.pop_front("s"), Some(2));
        assert_eq!(t.pop_front("s"), None);
        assert_eq!(t.pop_front("other"), None);
        assert_eq!(t.head_wait("s", 9.0), None, "empty queue has no head");
    }

    #[test]
    fn lease_bind_release() {
        let mut t = LeaseTable::default();
        t.bind(5, 1, "s", "spot", Some("alice"), 2.0);
        assert!(t.is_leased(5));
        assert_eq!(t.get(5).unwrap().worker, 1);
        assert_eq!(t.get(5).unwrap().site, "spot");
        assert_eq!(t.get(5).unwrap().tenant.as_deref(), Some("alice"));
        let info = t.release(5).unwrap();
        assert_eq!(info.study_key, "s");
        assert!(t.release(5).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let mut t = LeaseTable::default();
        t.bind(5, 1, "a", "cloud", None, 2.0);
        t.bind(6, 2, "b", "spot", Some("alice"), 3.0);
        t.push_back("b", 9, 1.0);
        t.push_back("b", 10, 2.0);
        let (l, q, c) = (t.leases_json(), t.queues_json(), t.requeue_counts_json());
        let mut back = LeaseTable::default();
        back.load_json(&l, &q, &c);
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(6).unwrap().study_key, "b");
        assert_eq!(back.get(6).unwrap().site, "spot", "site survives the segment");
        assert_eq!(back.get(6).unwrap().tenant.as_deref(), Some("alice"));
        assert_eq!(back.get(5).unwrap().tenant, None);
        assert_eq!(back.queue_depth(), 2);
        // Recovered queue entries read as waited-forever: never deferred.
        assert_eq!(back.head_wait("b", 0.0), Some(f64::INFINITY));
        assert_eq!(back.pop_front("b"), Some(9));
        assert_eq!(back.requeues(10), 1);
    }
}

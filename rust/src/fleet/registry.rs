//! Worker registry: who is in the fleet, where they run, and whether
//! their heartbeat lease is current.
//!
//! A worker is one training process on one node (the paper's
//! "computing instance"). It registers once with its site and GPU
//! profile, heartbeats to renew its lease deadline, and either
//! deregisters gracefully or vanishes — in which case expiry marks it
//! [`WorkerState::Lost`] and its trials are requeued (see
//! `fleet::lease`). Worker ids are allocated by the registry and
//! journaled in the `worker_register` record, so recovery reassigns the
//! same ids.

use crate::json::Value;
use std::collections::{HashMap, HashSet};

/// Worker lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Registered and heartbeating (or within its first lease window).
    Alive,
    /// Lease expired without a goodbye; its trials were requeued.
    Lost,
    /// Graceful shutdown via the deregister API.
    Deregistered,
}

impl WorkerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkerState::Alive => "alive",
            WorkerState::Lost => "lost",
            WorkerState::Deregistered => "deregistered",
        }
    }

    pub fn from_str(s: &str) -> Option<WorkerState> {
        match s {
            "alive" => Some(WorkerState::Alive),
            "lost" => Some(WorkerState::Lost),
            "deregistered" => Some(WorkerState::Deregistered),
            _ => None,
        }
    }
}

/// One fleet worker.
#[derive(Clone, Debug)]
pub struct WorkerInfo {
    pub id: u64,
    /// Client-chosen label, e.g. `"marconi100-07"`. Not unique — a
    /// respawned spot instance registers again under the same label and
    /// gets a fresh id.
    pub name: String,
    /// Resource-provider site (quota / fair-share domain).
    pub site: String,
    /// Free-form GPU/profile string for the dashboard.
    pub gpu: String,
    pub state: WorkerState,
    pub registered_at: f64,
    pub last_heartbeat: f64,
    /// Lease deadline: heartbeats push it forward; expiry fires when it
    /// passes. Liveness only — never persisted, reset after recovery.
    pub deadline: f64,
    /// Trials currently leased to this worker.
    pub leases: HashSet<u64>,
}

impl WorkerInfo {
    fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("id", self.id)
            .set("name", self.name.as_str())
            .set("site", self.site.as_str())
            .set("gpu", self.gpu.as_str())
            .set("state", self.state.as_str())
            .set("registered_at", self.registered_at)
            .set("last_heartbeat", self.last_heartbeat)
            .set("leases", self.leases.len());
        Value::Obj(o)
    }
}

/// The registry table. Part of `FleetState`, guarded by the fleet lock.
#[derive(Default)]
pub struct WorkerRegistry {
    workers: HashMap<u64, WorkerInfo>,
    next_id: u64,
}

impl WorkerRegistry {
    /// Next id to assign (persisted in the `worker_register` payload
    /// before [`WorkerRegistry::apply_register`] consumes it).
    pub fn next_id(&self) -> u64 {
        self.next_id.max(1)
    }

    /// Insert a worker with a pre-allocated id (live path and replay
    /// share this). Keeps the id counter ahead of every applied id.
    pub fn apply_register(
        &mut self,
        id: u64,
        name: &str,
        site: &str,
        gpu: &str,
        now: f64,
        deadline: f64,
    ) {
        self.workers.insert(
            id,
            WorkerInfo {
                id,
                name: name.to_string(),
                site: site.to_string(),
                gpu: gpu.to_string(),
                state: WorkerState::Alive,
                registered_at: now,
                last_heartbeat: now,
                deadline,
                leases: HashSet::new(),
            },
        );
        self.next_id = self.next_id.max(id + 1);
    }

    pub fn get(&self, id: u64) -> Option<&WorkerInfo> {
        self.workers.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut WorkerInfo> {
        self.workers.get_mut(&id)
    }

    pub fn site_of(&self, id: u64) -> Option<&str> {
        self.workers.get(&id).map(|w| w.site.as_str())
    }

    /// Renew a worker's lease. Errors if the worker is unknown or no
    /// longer alive (the caller maps these to 404 / 409).
    pub fn heartbeat(&mut self, id: u64, now: f64, ttl: f64) -> Result<&WorkerInfo, String> {
        let w = self
            .workers
            .get_mut(&id)
            .ok_or_else(|| format!("unknown worker {id}"))?;
        if w.state != WorkerState::Alive {
            return Err(format!(
                "worker {id} is {}: its lease expired, re-register",
                w.state.as_str()
            ));
        }
        w.last_heartbeat = now;
        w.deadline = now + ttl;
        Ok(&*w)
    }

    pub fn mark_lost(&mut self, id: u64, now: f64) {
        if let Some(w) = self.workers.get_mut(&id) {
            if w.state == WorkerState::Alive {
                w.state = WorkerState::Lost;
                w.deadline = now;
            }
        }
    }

    pub fn mark_deregistered(&mut self, id: u64) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.state = WorkerState::Deregistered;
        }
    }

    /// Attach/detach a trial lease to a worker's set.
    pub fn attach(&mut self, id: u64, trial_id: u64) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.leases.insert(trial_id);
        }
    }

    pub fn detach(&mut self, id: u64, trial_id: u64) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.leases.remove(&trial_id);
        }
    }

    /// Is this worker currently collectible by expiry? (re-check under
    /// the lock after the lock-free collection pass)
    pub fn is_expiry_candidate(&self, id: u64, now: f64) -> bool {
        match self.workers.get(&id) {
            Some(w) => {
                (w.state == WorkerState::Alive && w.deadline < now)
                    || (w.state != WorkerState::Alive && !w.leases.is_empty())
            }
            None => false,
        }
    }

    /// Drop retired (lost/deregistered, lease-free) workers beyond
    /// `max_dead`, oldest heartbeat first. Recent dead entries are kept
    /// so a straggler heartbeat still gets the informative 409, but the
    /// registry — and with it the fleet segment, `GET /api/workers` and
    /// the expiry sweep — stays bounded on spot-heavy fleets where
    /// every respawn registers a fresh id. Returns how many were
    /// removed. In-memory only: purged ids resurrected by log replay
    /// are re-trimmed by the first sweep, and the next compaction's
    /// segment drops them durably.
    pub fn gc_dead(&mut self, max_dead: usize) -> usize {
        let mut dead: Vec<(f64, u64)> = self
            .workers
            .values()
            .filter(|w| w.state != WorkerState::Alive && w.leases.is_empty())
            .map(|w| (w.last_heartbeat, w.id))
            .collect();
        if dead.len() <= max_dead {
            return 0;
        }
        dead.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let excess = dead.len() - max_dead;
        for (_, id) in dead.into_iter().take(excess) {
            self.workers.remove(&id);
        }
        excess
    }

    /// Push every alive worker's deadline to `now + ttl` (recovery
    /// grace: deadlines are liveness, not persisted state).
    pub fn reset_deadlines(&mut self, now: f64, ttl: f64) {
        for w in self.workers.values_mut() {
            if w.state == WorkerState::Alive {
                w.deadline = now + ttl;
                w.last_heartbeat = now;
            }
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &WorkerInfo> {
        self.workers.values()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn count(&self, state: WorkerState) -> usize {
        self.workers.values().filter(|w| w.state == state).count()
    }

    /// Workers as a JSON array, in id order (API + fleet segment).
    pub fn to_json(&self) -> Value {
        let mut ids: Vec<u64> = self.workers.keys().copied().collect();
        ids.sort_unstable();
        Value::Arr(ids.iter().map(|id| self.workers[id].to_json()).collect())
    }

    /// Rebuild from segment JSON. Lease sets are reattached by the
    /// caller from the lease table; deadlines are reset afterwards.
    pub fn load_json(&mut self, workers: &Value, next_id: u64) {
        self.workers.clear();
        self.next_id = next_id.max(1);
        for wv in workers.as_arr().unwrap_or(&[]) {
            let Some(id) = wv.get("id").as_u64() else { continue };
            let state = WorkerState::from_str(wv.get("state").as_str().unwrap_or("alive"))
                .unwrap_or(WorkerState::Alive);
            self.workers.insert(
                id,
                WorkerInfo {
                    id,
                    name: wv.get("name").as_str().unwrap_or("").to_string(),
                    site: wv.get("site").as_str().unwrap_or("").to_string(),
                    gpu: wv.get("gpu").as_str().unwrap_or("").to_string(),
                    state,
                    registered_at: wv.get("registered_at").as_f64().unwrap_or(0.0),
                    last_heartbeat: wv.get("last_heartbeat").as_f64().unwrap_or(0.0),
                    deadline: 0.0,
                    leases: HashSet::new(),
                },
            );
            self.next_id = self.next_id.max(id + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_heartbeat_lifecycle() {
        let mut r = WorkerRegistry::default();
        assert_eq!(r.next_id(), 1);
        let id = r.next_id();
        r.apply_register(id, "n1", "cloud", "a100", 0.0, 10.0);
        assert_eq!(r.next_id(), 2);
        assert_eq!(r.get(id).unwrap().state, WorkerState::Alive);
        let w = r.heartbeat(id, 5.0, 10.0).unwrap();
        assert_eq!(w.deadline, 15.0);
        r.mark_lost(id, 20.0);
        assert!(r.heartbeat(id, 21.0, 10.0).is_err(), "lost workers must re-register");
        assert!(r.heartbeat(99, 0.0, 10.0).is_err());
    }

    #[test]
    fn expiry_candidates() {
        let mut r = WorkerRegistry::default();
        r.apply_register(1, "n", "s", "g", 0.0, 10.0);
        assert!(!r.is_expiry_candidate(1, 5.0));
        assert!(r.is_expiry_candidate(1, 11.0));
        r.mark_lost(1, 11.0);
        assert!(!r.is_expiry_candidate(1, 12.0), "lost without leases");
        r.attach(1, 42);
        assert!(r.is_expiry_candidate(1, 12.0), "lost with orphan lease");
        r.detach(1, 42);
        assert!(!r.is_expiry_candidate(1, 12.0));
    }

    #[test]
    fn gc_dead_bounds_retired_workers() {
        let mut r = WorkerRegistry::default();
        for i in 1..=6u64 {
            r.apply_register(i, "n", "s", "g", i as f64, i as f64 + 10.0);
        }
        for i in 1..=4u64 {
            r.mark_lost(i, 20.0);
        }
        r.attach(4, 99); // lost but still holding a lease: not collectible
        assert_eq!(r.gc_dead(3), 0, "within the retention cap");
        assert_eq!(r.gc_dead(2), 1, "oldest lease-free dead worker dropped");
        assert!(r.get(1).is_none());
        assert!(r.get(2).is_some() && r.get(3).is_some());
        assert!(r.get(4).is_some(), "leased worker survives");
        assert_eq!(r.count(WorkerState::Alive), 2);
        // Ids keep resuming past purged workers.
        assert_eq!(r.next_id(), 7);
    }

    #[test]
    fn json_roundtrip_and_id_resume() {
        let mut r = WorkerRegistry::default();
        r.apply_register(3, "n3", "spot", "t4", 1.0, 11.0);
        r.apply_register(5, "n5", "hpc", "v100", 2.0, 12.0);
        r.mark_deregistered(3);
        let j = r.to_json();
        let mut back = WorkerRegistry::default();
        back.load_json(&j, r.next_id());
        assert_eq!(back.len(), 2);
        assert_eq!(back.next_id(), 6);
        assert_eq!(back.get(3).unwrap().state, WorkerState::Deregistered);
        assert_eq!(back.get(5).unwrap().site, "hpc");
        // Deadlines come back unset until reset_deadlines.
        assert_eq!(back.get(5).unwrap().deadline, 0.0);
        back.reset_deadlines(100.0, 30.0);
        assert_eq!(back.get(5).unwrap().deadline, 130.0);
    }
}

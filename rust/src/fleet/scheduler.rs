//! Site-aware admission: per-site / per-study / per-tenant concurrency
//! quotas with fair-share ordering, resolved through the
//! [`QuotaPolicy`](super::policy::QuotaPolicy) table.
//!
//! The scheduler answers one question at `ask` time: *may this worker
//! take one more trial of this study right now?* Four rules apply, in
//! order:
//!
//! 1. **study quota** — a study may hold at most `study_quota` leases
//!    across the whole fleet (0 = unlimited);
//! 2. **tenant quota** — the identity behind the auth token may hold at
//!    most its resolved tenant quota of leases, fleet-wide;
//! 3. **site quota** — a site may hold at most its resolved quota of
//!    leases (per-site override, then the uniform default; 0 = unlimited);
//! 4. **fair share** — when another study has recently been turned away
//!    from this site, a study already holding at least
//!    `⌈site_quota / claimants⌉` of the site's slots is denied even if
//!    slots are free, leaving them for the waiter.
//!
//! Rule 4 is what stops a greedy campaign from starving others: without
//! it, a study that filled the site first would keep every slot forever
//! (its finished trials are immediately replaced by its own next ask,
//! and the pull-based protocol gives the server no queue to reorder).
//! "Recently turned away" is a decaying *waiting* mark, retired on the
//! **fairness horizon** (`--fairness-horizon`, seconds): a study that
//! stops asking stops counting against the share within seconds, not
//! the fleet GC's hour-scale retention — an abandoned campaign must not
//! deflate everyone else's `div_ceil(n)` share until `gc_idle` finally
//! notices it.
//!
//! The scheduler also keeps a per-site *health ledger* (trials handed
//! out vs. trials lost to worker preemption) that the site-affinity
//! requeue preference consults: see [`Scheduler::site_preferred`].
//!
//! Denials map to HTTP 429 so clients back off and retry; they are
//! counted in `hopaas_fleet_quota_denials_total` and, per tenant, in
//! `hopaas_tenant_quota_denials_total`.

use super::FleetConfig;
use crate::coordinator::engine::ApiError;
use crate::json::Value;
use std::collections::HashMap;

/// Was this quota denial produced by the **tenant rule** (as opposed to
/// site/study capacity or fair share)? The engine keys the per-tenant
/// 429 metric on this, so a tenanted ask refused on site capacity is
/// charged to the site, not the tenant. Lives in this file, next to the
/// message construction in [`Scheduler::admit`], so the prefix and its
/// classifier cannot drift apart (see `tenant_denials_classified`).
pub fn is_tenant_denial(e: &ApiError) -> bool {
    matches!(e, ApiError::Quota(msg) if msg.starts_with("tenant '"))
}

/// Checked slot decrement shared by the three release ledgers: take one
/// from `key` (dropping the entry at zero) and report whether there was
/// a slot to take.
fn dec_slot(map: &mut HashMap<String, u32>, key: &str) -> bool {
    match map.get_mut(key) {
        Some(c) if *c > 0 => {
            *c -= 1;
            if *c == 0 {
                map.remove(key);
            }
            true
        }
        _ => false,
    }
}

/// Per-site admission state.
#[derive(Default)]
pub struct SiteState {
    /// Leases (plus in-flight admissions) per study on this site.
    counts: HashMap<String, u32>,
    /// Studies recently denied here → time of the last denial.
    waiting: HashMap<String, f64>,
    /// High-water mark of concurrently held slots (tests assert this
    /// never exceeds the quota).
    pub peak: u32,
    /// Last admission attempt — idle-site GC input. Site names are
    /// client-supplied strings, so the map must not grow forever.
    last_active: f64,
    /// Health ledger: trials bound to workers of this site…
    handed: u64,
    /// …and trials lost here (worker vanished, trial requeued or failed
    /// out of budget). Persisted in the fleet segment and rebuilt from
    /// replayed fleet records, so `--site-affinity` decisions survive a
    /// restart instead of resetting to "everyone is healthy".
    lost: u64,
}

impl SiteState {
    fn total(&self) -> u32 {
        self.counts.values().sum()
    }

    /// Fraction of this site's handouts that ended in a preemption.
    fn loss_rate(&self) -> f64 {
        let total = self.handed + self.lost;
        if total == 0 {
            0.0
        } else {
            self.lost as f64 / total as f64
        }
    }
}

/// Admission counters for every site, plus per-study and per-tenant
/// totals (both fleet-wide).
#[derive(Default)]
pub struct Scheduler {
    sites: HashMap<String, SiteState>,
    /// Leases (plus in-flight admissions) per study, fleet-wide.
    study_active: HashMap<String, u32>,
    /// Leases (plus in-flight admissions) per tenant, fleet-wide.
    tenant_active: HashMap<String, u32>,
}

impl Scheduler {
    /// Reserve one slot for `(site, study, tenant)` or say why not. The
    /// caller pairs every `Ok` with exactly one later
    /// [`Scheduler::release`] carrying the same keys.
    pub fn admit(
        &mut self,
        site: &str,
        study: &str,
        tenant: Option<&str>,
        now: f64,
        config: &FleetConfig,
    ) -> Result<(), ApiError> {
        let policy = &config.policy;
        if policy.study_quota > 0
            && self.study_active.get(study).copied().unwrap_or(0) >= policy.study_quota
        {
            return Err(ApiError::Quota(format!(
                "study quota reached ({} concurrent trials)",
                policy.study_quota
            )));
        }
        if let Some(tenant) = tenant {
            let tq = policy.tenant_quota_for(tenant);
            if tq > 0 && self.tenant_active.get(tenant).copied().unwrap_or(0) >= tq {
                // The `tenant '` prefix is what `is_tenant_denial`
                // classifies on — keep the two in sync.
                return Err(ApiError::Quota(format!(
                    "tenant '{tenant}' quota reached ({tq} concurrent trials)"
                )));
            }
        }
        let site_quota = policy.site_quota_for(site);
        let state = self.sites.entry(site.to_string()).or_default();
        state.last_active = now;
        if site_quota > 0 {
            // Waiting marks expire on the fairness horizon: a study that
            // stopped asking no longer claims a share (re-checked here,
            // at admission time, not just by the hour-scale fleet GC).
            let horizon = policy.fairness_horizon.max(1.0);
            state.waiting.retain(|_, t| now - *t < horizon);
            let total = state.total();
            let mine = state.counts.get(study).copied().unwrap_or(0);
            if total >= site_quota {
                state.waiting.insert(study.to_string(), now);
                return Err(ApiError::Quota(format!(
                    "site '{site}' at capacity ({site_quota} concurrent trials)"
                )));
            }
            let others_waiting = state.waiting.keys().any(|k| k != study);
            if others_waiting {
                let mut claimants: std::collections::HashSet<&str> = state
                    .counts
                    .iter()
                    .filter(|(_, &c)| c > 0)
                    .map(|(k, _)| k.as_str())
                    .collect();
                claimants.extend(state.waiting.keys().map(|k| k.as_str()));
                claimants.insert(study);
                let n = claimants.len() as u32;
                let share = site_quota.div_ceil(n);
                if mine >= share {
                    state.waiting.insert(study.to_string(), now);
                    return Err(ApiError::Quota(format!(
                        "fair share on site '{site}' reached \
                         ({mine}/{share} slots, {n} campaigns competing)"
                    )));
                }
            }
            state.waiting.remove(study);
        }
        *state.counts.entry(study.to_string()).or_insert(0) += 1;
        state.peak = state.peak.max(state.total());
        *self.study_active.entry(study.to_string()).or_insert(0) += 1;
        if let Some(tenant) = tenant {
            *self.tenant_active.entry(tenant.to_string()).or_insert(0) += 1;
        }
        Ok(())
    }

    /// Return one `(site, study, tenant)` slot (lease released, admission
    /// cancelled, or trial requeued). Returns `false` — and fails a debug
    /// assertion — if any of the three counters had no slot to return:
    /// a double release would silently corrupt quota headroom, so the
    /// engine's paths must release **exactly once** per admission (they
    /// gate every release on the lease table's single `release`).
    /// Counters never go below zero in release builds either way.
    pub fn release(&mut self, site: &str, study: &str, tenant: Option<&str>) -> bool {
        let mut balanced = match self.sites.get_mut(site) {
            Some(state) => dec_slot(&mut state.counts, study),
            None => false,
        };
        balanced &= dec_slot(&mut self.study_active, study);
        if let Some(tenant) = tenant {
            balanced &= dec_slot(&mut self.tenant_active, tenant);
        }
        debug_assert!(
            balanced,
            "slot released twice for site '{site}' study '{study}' tenant {tenant:?}"
        );
        balanced
    }

    /// Count a pre-existing lease without quota checks (recovery
    /// rebuild; quotas were enforced when the lease was granted).
    pub fn count_existing(&mut self, site: &str, study: &str, tenant: Option<&str>) {
        let state = self.sites.entry(site.to_string()).or_default();
        *state.counts.entry(study.to_string()).or_insert(0) += 1;
        state.peak = state.peak.max(state.total());
        *self.study_active.entry(study.to_string()).or_insert(0) += 1;
        if let Some(tenant) = tenant {
            *self.tenant_active.entry(tenant.to_string()).or_insert(0) += 1;
        }
    }

    /// Drop all usage counters (recovery rebuild); peaks and the health
    /// ledger survive. Fair-share *waiting* marks are dropped too: they
    /// are timestamps on the pre-restart clock, and the engine's time
    /// base restarts at zero — a stale mark would otherwise deflate
    /// every study's share until the ghost waiter aged out.
    pub fn reset_usage(&mut self) {
        for state in self.sites.values_mut() {
            state.counts.clear();
            state.waiting.clear();
        }
        self.study_active.clear();
        self.tenant_active.clear();
    }

    // --- site health (affinity input) ------------------------------------

    /// Record a trial bound to a worker of `site`.
    pub fn note_handout(&mut self, site: &str) {
        self.sites.entry(site.to_string()).or_default().handed += 1;
    }

    /// Record a trial lost on `site` (worker vanished, trial requeued).
    pub fn note_loss(&mut self, site: &str) {
        self.sites.entry(site.to_string()).or_default().lost += 1;
    }

    /// The persisted health ledger: `(site, handed, lost)` for every
    /// site with any history, sorted (deterministic segment bytes).
    pub fn health_json(&self) -> Value {
        let mut keys: Vec<&String> = self
            .sites
            .iter()
            .filter(|(_, s)| s.handed > 0 || s.lost > 0)
            .map(|(k, _)| k)
            .collect();
        keys.sort();
        Value::Arr(
            keys.iter()
                .map(|k| {
                    let s = &self.sites[*k];
                    let mut o = Value::obj();
                    o.set("site", k.as_str()).set("handed", s.handed).set("lost", s.lost);
                    Value::Obj(o)
                })
                .collect(),
        )
    }

    /// Restore the health ledger from a fleet segment (recovery; `Null`
    /// for pre-ledger segments is a no-op). Overwrites, never adds: the
    /// segment is the authoritative state at its cut, and the replayed
    /// record tail re-applies only post-cut handouts/losses.
    pub fn load_health(&mut self, v: &Value) {
        for entry in v.as_arr().unwrap_or(&[]) {
            let Some(site) = entry.get("site").as_str() else { continue };
            let state = self.sites.entry(site.to_string()).or_default();
            state.handed = entry.get("handed").as_u64().unwrap_or(0);
            state.lost = entry.get("lost").as_u64().unwrap_or(0);
        }
    }

    /// Is `site` healthy enough to be handed a requeued trial under the
    /// affinity preference? A site qualifies when its preemption rate is
    /// no worse than the fleet-wide mean — so in a uniform fleet every
    /// site qualifies, and a lone site always qualifies, but a spot pool
    /// bleeding workers defers to stabler sites (until the queue head
    /// has waited out the fairness horizon; the engine enforces that
    /// grace so affinity can never strand a trial).
    pub fn site_preferred(&self, site: &str) -> bool {
        let Some(me) = self.sites.get(site) else { return true };
        if self.sites.len() <= 1 {
            return true;
        }
        let mean = self.sites.values().map(SiteState::loss_rate).sum::<f64>()
            / self.sites.len() as f64;
        me.loss_rate() <= mean + 1e-9
    }

    /// Evict sites with no slots, no fresh waiters, and no admission
    /// attempt within `retention` seconds. Waiting marks expire on the
    /// (much shorter) fairness `horizon`, the same clock admission uses.
    /// Site names come from clients, so without this the map (and the
    /// `/api/stats` sites array and `hopaas_site_leases` label set)
    /// would grow one entry per distinct string ever seen. Returns how
    /// many were dropped.
    pub fn gc_idle(&mut self, now: f64, retention: f64, horizon: f64) -> usize {
        let before = self.sites.len();
        self.sites.retain(|_, s| {
            s.waiting.retain(|_, t| now - *t < horizon);
            s.total() > 0 || !s.waiting.is_empty() || now - s.last_active <= retention
        });
        before - self.sites.len()
    }

    // --- accessors (tests, metrics, invariants) ---------------------------

    /// Active slots on one site (tests/metrics).
    pub fn site_active(&self, site: &str) -> u32 {
        self.sites.get(site).map(|s| s.total()).unwrap_or(0)
    }

    /// Active slots across every site — must equal the live lease count
    /// whenever no admission is in flight (the prop-test invariant).
    pub fn total_active(&self) -> u64 {
        self.sites.values().map(|s| s.total() as u64).sum()
    }

    /// Sum of the per-study counters (same invariant, second ledger).
    pub fn study_active_total(&self) -> u64 {
        self.study_active.values().map(|&c| c as u64).sum()
    }

    /// Active slots held by one tenant.
    pub fn tenant_active(&self, tenant: &str) -> u32 {
        self.tenant_active.get(tenant).copied().unwrap_or(0)
    }

    /// Sum of the per-tenant counters (tenant-carrying leases only).
    pub fn tenant_active_total(&self) -> u64 {
        self.tenant_active.values().map(|&c| c as u64).sum()
    }

    /// `(site, active)` pairs for the labeled metrics gauge. Sites with
    /// no active slot are skipped: after a restart the persisted health
    /// ledger resurrects site entries no live lease re-established, and
    /// `/metrics` must not report that ghost occupancy (the `/api/stats`
    /// sites block still lists them, with their health). Dropping the
    /// series is also the live behavior the wholesale scrape-time
    /// snapshot gives once a site's last lease releases.
    pub fn site_loads(&self) -> Vec<(String, u32)> {
        let mut out: Vec<(String, u32)> = self
            .sites
            .iter()
            .filter(|(_, s)| s.total() > 0)
            .map(|(k, s)| (k.clone(), s.total()))
            .collect();
        out.sort();
        out
    }

    /// `(tenant, active)` pairs for the `hopaas_tenant_leases` gauge.
    pub fn tenant_loads(&self) -> Vec<(String, u32)> {
        let mut out: Vec<(String, u32)> = self
            .tenant_active
            .iter()
            .map(|(k, &c)| (k.clone(), c))
            .collect();
        out.sort();
        out
    }

    /// Per-site stats block for `/api/stats`, with the resolved quota
    /// and the health ledger.
    pub fn sites_json(&self, policy: &super::policy::QuotaPolicy) -> Value {
        let mut keys: Vec<&String> = self.sites.keys().collect();
        keys.sort();
        Value::Arr(
            keys.iter()
                .map(|k| {
                    let s = &self.sites[*k];
                    let mut o = Value::obj();
                    o.set("site", k.as_str())
                        .set("active", s.total())
                        .set("peak", s.peak)
                        .set("studies", s.counts.len())
                        .set("waiting", s.waiting.len())
                        .set("quota", policy.site_quota_for(k))
                        .set("handed", s.handed)
                        .set("lost", s.lost);
                    Value::Obj(o)
                })
                .collect(),
        )
    }

    /// Per-tenant stats block for `/api/stats`.
    pub fn tenants_json(&self, policy: &super::policy::QuotaPolicy) -> Value {
        let mut keys: Vec<&String> = self.tenant_active.keys().collect();
        keys.sort();
        Value::Arr(
            keys.iter()
                .map(|t| {
                    let mut o = Value::obj();
                    o.set("tenant", t.as_str())
                        .set("active", self.tenant_active[*t])
                        .set("quota", policy.tenant_quota_for(t));
                    Value::Obj(o)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::QuotaPolicy;
    use super::*;

    fn cfg(site_quota: u32, study_quota: u32) -> FleetConfig {
        FleetConfig {
            lease_timeout: Some(30.0),
            requeue_max: 3,
            policy: QuotaPolicy {
                site_quota,
                study_quota,
                fairness_horizon: 30.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn site_quota_enforced() {
        let mut s = Scheduler::default();
        let c = cfg(2, 0);
        s.admit("gpu", "a", None, 0.0, &c).unwrap();
        s.admit("gpu", "a", None, 0.0, &c).unwrap();
        assert!(matches!(s.admit("gpu", "a", None, 0.0, &c), Err(ApiError::Quota(_))));
        // A different site is unaffected.
        s.admit("cpu", "a", None, 0.0, &c).unwrap();
        assert!(s.release("gpu", "a", None));
        s.admit("gpu", "a", None, 1.0, &c).unwrap();
        assert_eq!(s.site_active("gpu"), 2);
        assert_eq!(s.sites.get("gpu").unwrap().peak, 2, "peak never exceeded quota");
    }

    #[test]
    fn per_site_override_beats_default() {
        let mut s = Scheduler::default();
        let mut c = cfg(1, 0);
        c.policy.site_quotas.insert("hpc".into(), 3);
        c.policy.site_quotas.insert("open".into(), 0);
        // Default site: capped at 1.
        s.admit("cloud", "a", None, 0.0, &c).unwrap();
        let err = s.admit("cloud", "a", None, 0.0, &c).unwrap_err();
        assert!(err.to_string().contains("site 'cloud'"), "{err}");
        // Overridden site: capped at 3.
        for _ in 0..3 {
            s.admit("hpc", "a", None, 0.0, &c).unwrap();
        }
        assert!(matches!(s.admit("hpc", "a", None, 0.0, &c), Err(ApiError::Quota(_))));
        // Explicit 0 override lifts the cap entirely.
        for _ in 0..8 {
            s.admit("open", "a", None, 0.0, &c).unwrap();
        }
        assert_eq!(s.site_active("open"), 8);
    }

    #[test]
    fn tenant_quota_enforced_with_attribution() {
        let mut s = Scheduler::default();
        let mut c = cfg(0, 0);
        c.policy.tenant_quota = 2;
        c.policy.tenant_quotas.insert("vip".into(), 3);
        s.admit("gpu", "a", Some("alice"), 0.0, &c).unwrap();
        s.admit("cpu", "b", Some("alice"), 0.0, &c).unwrap();
        // Tenant quota spans sites and studies; the denial names the
        // tenant so 429s are attributable.
        let err = s.admit("hpc", "c", Some("alice"), 0.0, &c).unwrap_err();
        assert!(matches!(err, ApiError::Quota(_)));
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        // Another tenant is unaffected; the override beats the default.
        s.admit("gpu", "a", Some("bob"), 0.0, &c).unwrap();
        for _ in 0..3 {
            s.admit("gpu", "a", Some("vip"), 0.0, &c).unwrap();
        }
        assert!(s.admit("gpu", "a", Some("vip"), 0.0, &c).is_err());
        // Tenant-less asks are never tenant-limited.
        s.admit("gpu", "a", None, 0.0, &c).unwrap();
        // Release frees tenant headroom.
        assert!(s.release("gpu", "a", Some("alice")));
        s.admit("gpu", "a", Some("alice"), 1.0, &c).unwrap();
        assert_eq!(s.tenant_active("alice"), 2);
        assert_eq!(s.tenant_active("vip"), 3);
    }

    #[test]
    fn tenant_denials_classified() {
        // The classifier and the message construction live in this
        // file; this pins their agreement so a rewording cannot
        // silently break the per-tenant 429 metric.
        let mut s = Scheduler::default();
        let mut c = cfg(1, 1);
        c.policy.tenant_quota = 1;
        s.admit("gpu", "a", Some("t"), 0.0, &c).unwrap();
        let tenant_err = s.admit("cpu", "b", Some("t"), 0.0, &c).unwrap_err();
        assert!(is_tenant_denial(&tenant_err), "{tenant_err}");
        let study_err = s.admit("cpu", "a", Some("u"), 0.0, &c).unwrap_err();
        assert!(!is_tenant_denial(&study_err), "{study_err}");
        let site_err = s.admit("gpu", "b", Some("u"), 0.0, &c).unwrap_err();
        assert!(!is_tenant_denial(&site_err), "{site_err}");
        assert!(!is_tenant_denial(&ApiError::NotFound("tenant 'x'".into())));
    }

    #[test]
    fn study_quota_spans_sites() {
        let mut s = Scheduler::default();
        let c = cfg(0, 2);
        s.admit("gpu", "a", None, 0.0, &c).unwrap();
        s.admit("cpu", "a", None, 0.0, &c).unwrap();
        assert!(matches!(s.admit("hpc", "a", None, 0.0, &c), Err(ApiError::Quota(_))));
        s.admit("hpc", "b", None, 0.0, &c).unwrap();
    }

    #[test]
    fn fair_share_yields_to_waiting_study() {
        let mut s = Scheduler::default();
        let c = cfg(4, 0);
        // Greedy study A fills the site.
        for _ in 0..4 {
            s.admit("gpu", "a", None, 0.0, &c).unwrap();
        }
        // B is turned away (site full) and marked waiting.
        assert!(s.admit("gpu", "b", None, 1.0, &c).is_err());
        // One of A's trials finishes; A asks again first, but its share
        // with B waiting is ceil(4/2) = 2 and it holds 3 → denied.
        assert!(s.release("gpu", "a", None));
        assert!(s.admit("gpu", "a", None, 2.0, &c).is_err());
        // B takes the free slot.
        s.admit("gpu", "b", None, 3.0, &c).unwrap();
        // Converges to 2/2: A drains to 2, then both hold their share.
        assert!(s.release("gpu", "a", None));
        s.admit("gpu", "b", None, 4.0, &c).unwrap();
        assert_eq!(s.site_active("gpu"), 4);
        assert!(s.admit("gpu", "a", None, 5.0, &c).is_err(), "A at share while B waits");
        // Once B stops waiting (horizon passes), A can grow again.
        assert!(s.release("gpu", "b", None));
        s.admit("gpu", "a", None, 100.0, &c).unwrap();
    }

    /// Regression (fair-share deflation): an abandoned campaign's
    /// waiting mark must stop deflating other studies' share after the
    /// fairness horizon — not after the hour-scale `gc_idle` retention.
    #[test]
    fn abandoned_waiter_expires_on_fairness_horizon() {
        let mut s = Scheduler::default();
        let mut c = cfg(4, 0);
        c.policy.fairness_horizon = 5.0;
        // A fills the site; B is denied once and then abandons the
        // campaign (never asks again).
        for _ in 0..4 {
            s.admit("gpu", "a", None, 0.0, &c).unwrap();
        }
        assert!(s.admit("gpu", "b", None, 1.0, &c).is_err());
        // Within the horizon the ghost of B still claims its share: A
        // may not re-grow past ceil(4/2)=2.
        assert!(s.release("gpu", "a", None));
        assert!(s.admit("gpu", "a", None, 2.0, &c).is_err(), "B's share held");
        // Past the horizon — but *far* before the 1 h GC retention, and
        // with no gc_idle call at all — A gets the full site back.
        s.admit("gpu", "a", None, 6.5, &c).unwrap();
        s.admit("gpu", "a", None, 6.5, &c).unwrap();
        assert_eq!(s.site_active("gpu"), 4, "abandoned waiter released the share");
    }

    #[test]
    fn single_study_uses_full_site() {
        // No competitors → no fair-share clamp.
        let mut s = Scheduler::default();
        let c = cfg(4, 0);
        for _ in 0..4 {
            s.admit("gpu", "a", None, 0.0, &c).unwrap();
        }
        assert_eq!(s.site_active("gpu"), 4);
    }

    #[test]
    fn release_is_exactly_once() {
        let mut s = Scheduler::default();
        let c = cfg(0, 0);
        s.admit("gpu", "a", Some("t"), 0.0, &c).unwrap();
        assert!(s.release("gpu", "a", Some("t")), "first release balances");
        assert_eq!(s.total_active(), 0);
        assert_eq!(s.study_active_total(), 0);
        assert_eq!(s.tenant_active_total(), 0);
        // A second release must not mint headroom — counters stay at 0.
        // (In debug builds the engine paths would trip the assertion;
        // here we exercise the release-build behavior via the flag.)
        if cfg!(not(debug_assertions)) {
            assert!(!s.release("gpu", "a", Some("t")), "double release detected");
            assert_eq!(s.total_active(), 0);
            assert_eq!(s.tenant_active_total(), 0);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "slot released twice")]
    fn double_release_asserts_in_debug() {
        let mut s = Scheduler::default();
        let c = cfg(0, 0);
        s.admit("gpu", "a", None, 0.0, &c).unwrap();
        assert!(s.release("gpu", "a", None));
        s.release("gpu", "a", None); // panics: nothing left to release
    }

    #[test]
    fn site_health_drives_affinity_preference() {
        let mut s = Scheduler::default();
        // One site: always preferred (nobody to defer to).
        s.note_handout("spot");
        s.note_loss("spot");
        assert!(s.site_preferred("spot"));
        // A stable site appears: spot's loss rate (0.5) is now above the
        // mean (0.25) while stable's (0.0) is below it.
        s.note_handout("stable");
        assert!(!s.site_preferred("spot"));
        assert!(s.site_preferred("stable"));
        assert!(s.site_preferred("never-seen"), "unknown sites are not penalized");
        // Uniform fleets: everyone at the mean, everyone preferred.
        let mut u = Scheduler::default();
        u.note_handout("a");
        u.note_handout("b");
        assert!(u.site_preferred("a") && u.site_preferred("b"));
    }

    #[test]
    fn gc_idle_evicts_stale_sites_only() {
        let mut s = Scheduler::default();
        let c = cfg(0, 0);
        s.admit("busy", "a", None, 0.0, &c).unwrap();
        s.admit("idle", "a", None, 0.0, &c).unwrap();
        assert!(s.release("idle", "a", None));
        // "idle" has no slots but was active recently: kept.
        assert_eq!(s.gc_idle(10.0, 3600.0, 30.0), 0);
        // Past the retention window it goes; "busy" still holds a slot.
        assert_eq!(s.gc_idle(10_000.0, 3600.0, 30.0), 1);
        assert_eq!(s.site_loads(), vec![("busy".to_string(), 1)]);
    }

    #[test]
    fn rebuild_counts_path() {
        let mut s = Scheduler::default();
        let c = cfg(2, 0);
        s.admit("gpu", "a", Some("t1"), 0.0, &c).unwrap();
        s.reset_usage();
        assert_eq!(s.site_active("gpu"), 0);
        assert_eq!(s.tenant_active("t1"), 0);
        s.count_existing("gpu", "a", Some("t1"));
        s.count_existing("gpu", "a", None);
        assert_eq!(s.site_active("gpu"), 2);
        assert_eq!(s.tenant_active("t1"), 1);
        let loads = s.site_loads();
        assert_eq!(loads, vec![("gpu".to_string(), 2)]);
        assert_eq!(s.tenant_loads(), vec![("t1".to_string(), 1)]);
    }

    #[test]
    fn health_ledger_roundtrips_and_usage_reset_keeps_it() {
        let mut s = Scheduler::default();
        let c = cfg(4, 0);
        s.admit("spot", "a", Some("t"), 0.0, &c).unwrap();
        s.note_handout("spot");
        s.note_handout("spot");
        s.note_loss("spot");
        s.note_handout("stable");
        // A denied study leaves a waiting mark on the full site.
        for _ in 0..3 {
            s.admit("spot", "a", None, 0.0, &c).unwrap();
        }
        assert!(s.admit("spot", "b", None, 1.0, &c).is_err());
        let health = s.health_json();
        // Sorted, only sites with history, exact counters.
        assert_eq!(health.at(0).get("site").as_str(), Some("spot"));
        assert_eq!(health.at(0).get("handed").as_u64(), Some(2));
        assert_eq!(health.at(0).get("lost").as_u64(), Some(1));
        assert_eq!(health.at(1).get("site").as_str(), Some("stable"));
        assert_eq!(health.as_arr().unwrap().len(), 2);
        // reset_usage (the recovery rebuild) drops slots AND stale
        // waiting marks, but the health ledger survives it.
        s.reset_usage();
        assert_eq!(s.site_active("spot"), 0);
        s.admit("spot", "a", None, 0.5, &c).unwrap();
        s.admit("spot", "a", None, 0.5, &c).unwrap();
        s.admit("spot", "a", None, 0.5, &c).unwrap();
        assert_eq!(s.site_active("spot"), 3, "ghost waiter gone after reset");
        assert_eq!(s.health_json().at(0).get("handed").as_u64(), Some(2));
        // Round-trip into a fresh scheduler: preference is identical.
        let mut back = Scheduler::default();
        back.load_health(&health);
        assert!(!back.site_preferred("spot"), "loss rate above the mean survives");
        assert!(back.site_preferred("stable"));
        // Pre-ledger segments (no "sites" block) are a clean no-op.
        back.load_health(&Value::Null);
        assert!(!back.site_preferred("spot"));
    }

    /// Regression (ghost occupancy): a site entry resurrected only by
    /// the persisted health ledger — no live lease — must not export a
    /// `hopaas_site_leases` series, while `/api/stats` keeps reporting
    /// its health.
    #[test]
    fn metrics_gauge_skips_sites_without_active_slots() {
        let mut s = Scheduler::default();
        let c = cfg(0, 0);
        let mut ghost = Scheduler::default();
        ghost.note_handout("vanished");
        ghost.note_loss("vanished");
        s.load_health(&ghost.health_json());
        s.admit("live", "a", None, 0.0, &c).unwrap();
        assert_eq!(s.site_loads(), vec![("live".to_string(), 1)]);
        let stats = s.sites_json(&c.policy);
        assert_eq!(stats.as_arr().unwrap().len(), 2, "stats still list the ghost");
        // Releasing the live slot drops its series too (wholesale
        // scrape-time snapshot semantics).
        assert!(s.release("live", "a", None));
        assert!(s.site_loads().is_empty());
    }

    #[test]
    fn stats_json_carry_quota_and_tenants() {
        let mut s = Scheduler::default();
        let mut c = cfg(4, 0);
        c.policy.site_quotas.insert("hpc".into(), 64);
        c.policy.tenant_quota = 2;
        s.admit("hpc", "a", Some("alice"), 0.0, &c).unwrap();
        let sites = s.sites_json(&c.policy);
        assert_eq!(sites.at(0).get("site").as_str(), Some("hpc"));
        assert_eq!(sites.at(0).get("quota").as_u64(), Some(64), "resolved quota");
        let tenants = s.tenants_json(&c.policy);
        assert_eq!(tenants.at(0).get("tenant").as_str(), Some("alice"));
        assert_eq!(tenants.at(0).get("active").as_u64(), Some(1));
        assert_eq!(tenants.at(0).get("quota").as_u64(), Some(2));
    }
}

//! Site-aware admission: per-site / per-study concurrency quotas with
//! fair-share ordering.
//!
//! The scheduler answers one question at `ask` time: *may this worker
//! take one more trial of this study right now?* Three rules apply, in
//! order:
//!
//! 1. **study quota** — a study may hold at most `study_quota` leases
//!    across the whole fleet (0 = unlimited);
//! 2. **site quota** — a site may hold at most `site_quota` leases
//!    (0 = unlimited);
//! 3. **fair share** — when another study has recently been turned away
//!    from this site, a study already holding at least
//!    `⌈site_quota / claimants⌉` of the site's slots is denied even if
//!    slots are free, leaving them for the waiter.
//!
//! Rule 3 is what stops a greedy campaign from starving others: without
//! it, a study that filled the site first would keep every slot forever
//! (its finished trials are immediately replaced by its own next ask,
//! and the pull-based protocol gives the server no queue to reorder).
//! "Recently turned away" is a decaying *waiting* mark — a denied study
//! is remembered for one lease-timeout window; studies that stop asking
//! stop counting against the share.
//!
//! Denials map to HTTP 429 so clients back off and retry; they are
//! counted in `hopaas_fleet_quota_denials_total`.

use super::FleetConfig;
use crate::coordinator::engine::ApiError;
use crate::json::Value;
use std::collections::HashMap;

/// Per-site admission state.
#[derive(Default)]
pub struct SiteState {
    /// Leases (plus in-flight admissions) per study on this site.
    counts: HashMap<String, u32>,
    /// Studies recently denied here → time of the last denial.
    waiting: HashMap<String, f64>,
    /// High-water mark of concurrently held slots (tests assert this
    /// never exceeds the quota).
    pub peak: u32,
    /// Last admission attempt — idle-site GC input. Site names are
    /// client-supplied strings, so the map must not grow forever.
    last_active: f64,
}

impl SiteState {
    fn total(&self) -> u32 {
        self.counts.values().sum()
    }
}

/// Admission counters for every site, plus the per-study totals.
#[derive(Default)]
pub struct Scheduler {
    sites: HashMap<String, SiteState>,
    /// Leases (plus in-flight admissions) per study, fleet-wide.
    study_active: HashMap<String, u32>,
}

impl Scheduler {
    /// Reserve one slot for `(site, study)` or say why not. The caller
    /// pairs every `Ok` with a later [`Scheduler::release`].
    pub fn admit(
        &mut self,
        site: &str,
        study: &str,
        now: f64,
        config: &FleetConfig,
    ) -> Result<(), ApiError> {
        if config.study_quota > 0
            && self.study_active.get(study).copied().unwrap_or(0) >= config.study_quota
        {
            return Err(ApiError::Quota(format!(
                "study quota reached ({} concurrent trials)",
                config.study_quota
            )));
        }
        let state = self.sites.entry(site.to_string()).or_default();
        state.last_active = now;
        if config.site_quota > 0 {
            // Waiting marks decay after one lease window: a study that
            // stopped asking no longer claims a share.
            let window = config.lease_timeout.unwrap_or(30.0).max(1.0);
            state.waiting.retain(|_, t| now - *t < window);
            let total = state.total();
            let mine = state.counts.get(study).copied().unwrap_or(0);
            if total >= config.site_quota {
                state.waiting.insert(study.to_string(), now);
                return Err(ApiError::Quota(format!(
                    "site '{site}' at capacity ({} concurrent trials)",
                    config.site_quota
                )));
            }
            let others_waiting = state.waiting.keys().any(|k| k != study);
            if others_waiting {
                let mut claimants: std::collections::HashSet<&str> = state
                    .counts
                    .iter()
                    .filter(|(_, &c)| c > 0)
                    .map(|(k, _)| k.as_str())
                    .collect();
                claimants.extend(state.waiting.keys().map(|k| k.as_str()));
                claimants.insert(study);
                let n = claimants.len() as u32;
                let share = config.site_quota.div_ceil(n);
                if mine >= share {
                    state.waiting.insert(study.to_string(), now);
                    return Err(ApiError::Quota(format!(
                        "fair share on site '{site}' reached \
                         ({mine}/{share} slots, {n} campaigns competing)"
                    )));
                }
            }
            state.waiting.remove(study);
        }
        *state.counts.entry(study.to_string()).or_insert(0) += 1;
        state.peak = state.peak.max(state.total());
        *self.study_active.entry(study.to_string()).or_insert(0) += 1;
        Ok(())
    }

    /// Return one `(site, study)` slot (lease released, admission
    /// cancelled, or trial requeued).
    pub fn release(&mut self, site: &str, study: &str) {
        if let Some(state) = self.sites.get_mut(site) {
            if let Some(c) = state.counts.get_mut(study) {
                *c = c.saturating_sub(1);
                if *c == 0 {
                    state.counts.remove(study);
                }
            }
        }
        if let Some(c) = self.study_active.get_mut(study) {
            *c = c.saturating_sub(1);
            if *c == 0 {
                self.study_active.remove(study);
            }
        }
    }

    /// Count a pre-existing lease without quota checks (recovery
    /// rebuild; quotas were enforced when the lease was granted).
    pub fn count_existing(&mut self, site: &str, study: &str) {
        let state = self.sites.entry(site.to_string()).or_default();
        *state.counts.entry(study.to_string()).or_insert(0) += 1;
        state.peak = state.peak.max(state.total());
        *self.study_active.entry(study.to_string()).or_insert(0) += 1;
    }

    /// Drop all usage counters (recovery rebuild); peaks survive.
    pub fn clear_counts(&mut self) {
        for state in self.sites.values_mut() {
            state.counts.clear();
        }
        self.study_active.clear();
    }

    /// Evict sites with no slots, no fresh waiters, and no admission
    /// attempt within `retention` seconds. Site names come from
    /// clients, so without this the map (and the `/api/stats` sites
    /// array and `hopaas_site_leases` label set) would grow one entry
    /// per distinct string ever seen. Returns how many were dropped.
    pub fn gc_idle(&mut self, now: f64, retention: f64) -> usize {
        let before = self.sites.len();
        self.sites.retain(|_, s| {
            s.waiting.retain(|_, t| now - *t < retention);
            s.total() > 0 || !s.waiting.is_empty() || now - s.last_active <= retention
        });
        before - self.sites.len()
    }

    /// Active slots on one site (tests/metrics).
    pub fn site_active(&self, site: &str) -> u32 {
        self.sites.get(site).map(|s| s.total()).unwrap_or(0)
    }

    /// `(site, active)` pairs for the labeled metrics gauge.
    pub fn site_loads(&self) -> Vec<(String, u32)> {
        let mut out: Vec<(String, u32)> = self
            .sites
            .iter()
            .map(|(k, s)| (k.clone(), s.total()))
            .collect();
        out.sort();
        out
    }

    /// Per-site stats block for `/api/stats`.
    pub fn sites_json(&self) -> Value {
        let mut keys: Vec<&String> = self.sites.keys().collect();
        keys.sort();
        Value::Arr(
            keys.iter()
                .map(|k| {
                    let s = &self.sites[*k];
                    let mut o = Value::obj();
                    o.set("site", k.as_str())
                        .set("active", s.total())
                        .set("peak", s.peak)
                        .set("studies", s.counts.len())
                        .set("waiting", s.waiting.len());
                    Value::Obj(o)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(site_quota: u32, study_quota: u32) -> FleetConfig {
        FleetConfig {
            lease_timeout: Some(30.0),
            site_quota,
            study_quota,
            requeue_max: 3,
        }
    }

    #[test]
    fn site_quota_enforced() {
        let mut s = Scheduler::default();
        let c = cfg(2, 0);
        s.admit("gpu", "a", 0.0, &c).unwrap();
        s.admit("gpu", "a", 0.0, &c).unwrap();
        assert!(matches!(s.admit("gpu", "a", 0.0, &c), Err(ApiError::Quota(_))));
        // A different site is unaffected.
        s.admit("cpu", "a", 0.0, &c).unwrap();
        s.release("gpu", "a");
        s.admit("gpu", "a", 1.0, &c).unwrap();
        assert_eq!(s.site_active("gpu"), 2);
        assert_eq!(s.sites.get("gpu").unwrap().peak, 2, "peak never exceeded quota");
    }

    #[test]
    fn study_quota_spans_sites() {
        let mut s = Scheduler::default();
        let c = cfg(0, 2);
        s.admit("gpu", "a", 0.0, &c).unwrap();
        s.admit("cpu", "a", 0.0, &c).unwrap();
        assert!(matches!(s.admit("hpc", "a", 0.0, &c), Err(ApiError::Quota(_))));
        s.admit("hpc", "b", 0.0, &c).unwrap();
    }

    #[test]
    fn fair_share_yields_to_waiting_study() {
        let mut s = Scheduler::default();
        let c = cfg(4, 0);
        // Greedy study A fills the site.
        for _ in 0..4 {
            s.admit("gpu", "a", 0.0, &c).unwrap();
        }
        // B is turned away (site full) and marked waiting.
        assert!(s.admit("gpu", "b", 1.0, &c).is_err());
        // One of A's trials finishes; A asks again first, but its share
        // with B waiting is ceil(4/2) = 2 and it holds 3 → denied.
        s.release("gpu", "a");
        assert!(s.admit("gpu", "a", 2.0, &c).is_err());
        // B takes the free slot.
        s.admit("gpu", "b", 3.0, &c).unwrap();
        // Converges to 2/2: A drains to 2, then both hold their share.
        s.release("gpu", "a");
        s.admit("gpu", "b", 4.0, &c).unwrap();
        assert_eq!(s.site_active("gpu"), 4);
        assert!(s.admit("gpu", "a", 5.0, &c).is_err(), "A at share while B waits");
        // Once B stops waiting (decay window passes), A can grow again.
        s.release("gpu", "b");
        s.admit("gpu", "a", 100.0, &c).unwrap();
    }

    #[test]
    fn single_study_uses_full_site() {
        // No competitors → no fair-share clamp.
        let mut s = Scheduler::default();
        let c = cfg(4, 0);
        for _ in 0..4 {
            s.admit("gpu", "a", 0.0, &c).unwrap();
        }
        assert_eq!(s.site_active("gpu"), 4);
    }

    #[test]
    fn gc_idle_evicts_stale_sites_only() {
        let mut s = Scheduler::default();
        let c = cfg(0, 0);
        s.admit("busy", "a", 0.0, &c).unwrap();
        s.admit("idle", "a", 0.0, &c).unwrap();
        s.release("idle", "a");
        // "idle" has no slots but was active recently: kept.
        assert_eq!(s.gc_idle(10.0, 3600.0), 0);
        // Past the retention window it goes; "busy" still holds a slot.
        assert_eq!(s.gc_idle(10_000.0, 3600.0), 1);
        assert_eq!(s.site_loads(), vec![("busy".to_string(), 1)]);
    }

    #[test]
    fn rebuild_counts_path() {
        let mut s = Scheduler::default();
        let c = cfg(2, 0);
        s.admit("gpu", "a", 0.0, &c).unwrap();
        s.clear_counts();
        assert_eq!(s.site_active("gpu"), 0);
        s.count_existing("gpu", "a");
        s.count_existing("gpu", "a");
        assert_eq!(s.site_active("gpu"), 2);
        let loads = s.site_loads();
        assert_eq!(loads, vec![("gpu".to_string(), 2)]);
    }
}

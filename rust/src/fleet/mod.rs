//! Fleet subsystem: worker registry, heartbeat leases, site-aware
//! trial scheduling and the tenant-aware quota policy.
//!
//! The paper's §4 deployment coordinates "more than twenty concurrent
//! and diverse computing nodes" — CINECA MARCONI 100, INFN Cloud,
//! private boxes, commercial spot instances — but the seed server had no
//! notion of a *worker*: trials were handed to anonymous `ask` calls and
//! a vanished node was only noticed by the passive `reap_stale` sweep,
//! hours later. This module makes the fleet first-class:
//!
//! * **registry** ([`registry`]): workers announce themselves
//!   (`POST /api/workers/register`) with a site / GPU profile and renew
//!   a *worker lease* with heartbeats. A worker whose deadline passes is
//!   marked lost.
//! * **leases** ([`lease`]): every worker-bound `ask` binds the trial to
//!   its worker's lease. Heartbeats renew all of a worker's trial leases
//!   at once; when the worker is lost, each of its running trials is
//!   deterministically *requeued* (handed, with its original id, number
//!   and parameters, to the next eligible `ask` of the same study) or
//!   failed once its requeue budget is spent — no reaper involved.
//! * **scheduler** ([`scheduler`]): per-site, per-study and per-tenant
//!   concurrency quotas with fair-share admission, so one greedy
//!   campaign — or one greedy user — cannot starve the others off a
//!   shared site.
//! * **policy** ([`policy`]): the quota table the scheduler resolves per
//!   admission — per-site overrides, tenant quotas keyed by the auth
//!   token's identity, the fair-share fairness horizon and the
//!   site-affinity requeue preference.
//!
//! ## Lease state machine
//!
//! ```text
//!       ask(worker=w)                 heartbeat(w)
//!   ──────────────────▶  LEASED(w) ◀───────────────┐ (deadline renewed)
//!                           │  │                   │
//!      tell/fail/prune      │  │ w's deadline passes
//!   ◀───────(released)──────┘  ▼
//!                           REQUEUED ──ask(worker=w')──▶ LEASED(w')
//!                              │
//!                              │ requeue budget spent
//!                              ▼
//!                           FAILED (durable trial_fail)
//! ```
//!
//! ## Durability
//!
//! Lease *structure* is journaled through the engine's WAL
//! (`worker_register`, `lease_bind`, `trial_requeue`, `worker_lost`,
//! `worker_deregister` records, stamped with the reserved
//! [`FLEET_SHARD`](crate::store::FLEET_SHARD) id) and snapshotted into
//! `snapshot.fleet.json` at compaction, so the fleet survives recovery
//! exactly like trials do. The `lease_bind` payload carries the
//! admission keys (site, tenant), so recovery rebuilds the scheduler's
//! per-site and per-tenant counters exactly as live admission counted
//! them. Lease *deadlines* are deliberately not persisted — they are
//! liveness, not state: recovery resets every surviving worker's
//! deadline to `now + lease_timeout`, giving live workers one heartbeat
//! interval to reclaim their leases before expiry requeues their trials.
//! The site *health ledger* behind the affinity preference (`handed` /
//! `lost` per site) **is** persisted: the fleet segment carries it, and
//! replayed `lease_bind` / `trial_requeue` / `site_loss` records rebuild
//! the post-cut tail — so `--site-affinity` keeps deferring to a lossy
//! site across a restart instead of silently resetting to "everyone is
//! healthy" for the first minutes of a resumed campaign.

pub mod lease;
pub mod policy;
pub mod registry;
pub mod scheduler;

pub use policy::QuotaPolicy;
pub use registry::{WorkerInfo, WorkerState};

use crate::coordinator::engine::ApiError;
use crate::json::Value;
use crate::sync::MutexExt;
use lease::LeaseTable;
use policy::TenantRateLedger;
use registry::WorkerRegistry;
use scheduler::Scheduler;
use std::collections::HashSet;
use std::sync::{Mutex, MutexGuard};

/// Fleet tuning, derived from the engine config.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Worker lease duration in seconds; heartbeats renew it. `None`
    /// disables expiry (leases then only release on tell/fail/prune).
    pub lease_timeout: Option<f64>,
    /// How many times a trial may be requeued after losing its worker
    /// before it is failed for good.
    pub requeue_max: u32,
    /// Retired (lost/deregistered, lease-free) workers kept for
    /// attribution before the fleet GC drops them.
    pub dead_worker_keep: usize,
    /// Seconds a site may sit idle (no slots, no waiters, no admission
    /// attempts) before the fleet GC evicts its scheduler entry.
    pub site_idle_retention: f64,
    /// The admission quota table (site/study/tenant quotas, fairness
    /// horizon, site affinity).
    pub policy: QuotaPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            lease_timeout: Some(60.0),
            requeue_max: 3,
            dead_worker_keep: 1024,
            site_idle_retention: 3600.0,
            policy: QuotaPolicy::default(),
        }
    }
}

/// The fleet tables, engine-global (workers span studies on every
/// shard). One mutex guards all three parts because every operation
/// touches at least two of them; the lock is a *leaf* in the engine's
/// ordering — it may be taken while holding a shard lock, never the
/// reverse — so no cycle with the shard/directory/router locks exists.
pub struct Fleet {
    state: Mutex<FleetState>,
    /// Worker-less ask-rate ledger. Its own (leaf) mutex, separate from
    /// the fleet tables: legacy asks must not serialize on the fleet
    /// lock just to be rate-checked, and a fleet that was never used
    /// still rate-limits.
    ask_rates: Mutex<TenantRateLedger>,
    pub config: FleetConfig,
}

/// Everything behind the fleet lock.
#[derive(Default)]
pub struct FleetState {
    pub registry: WorkerRegistry,
    pub leases: LeaseTable,
    pub sched: Scheduler,
}

impl Fleet {
    pub fn new(config: FleetConfig) -> Fleet {
        Fleet {
            state: Mutex::new(FleetState::default()),
            ask_rates: Mutex::new(TenantRateLedger::default()),
            config,
        }
    }

    /// Lock the fleet tables (leaf lock; see type docs).
    pub fn lock(&self) -> MutexGuard<'_, FleetState> {
        self.state.lock_safe()
    }

    /// Effective lease duration (infinite when expiry is disabled).
    pub fn ttl(&self) -> f64 {
        self.config.lease_timeout.unwrap_or(f64::INFINITY)
    }

    /// Windowed ask-rate admission for a *worker-less* (lease-less) ask
    /// by `tenant`: records the ask and returns `Ok`, or denies with a
    /// tenant-attributed 429. Worker-bound asks are bounded by the
    /// lease quotas instead and never consult this ledger.
    pub fn note_legacy_ask(&self, tenant: &str, now: f64) -> Result<(), ApiError> {
        let policy = &self.config.policy;
        if policy.tenant_ask_rate == 0 {
            return Ok(());
        }
        self.ask_rates.lock_safe().note_ask(
            tenant,
            now,
            policy.tenant_ask_rate,
            policy.tenant_ask_window,
        )
    }

    /// Sweep expired tenants out of the ask-rate ledger (tenant names
    /// are client-influenced strings; the map must not grow forever).
    pub fn gc_ask_rates(&self, now: f64) {
        if self.config.policy.tenant_ask_rate > 0 {
            self.ask_rates.lock_safe().gc(now, self.config.policy.tenant_ask_window);
        }
    }
}

impl FleetState {
    /// Quota/fair-share admission for a worker-bound ask. Reserves one
    /// scheduling slot and returns the **admission site** — the key the
    /// slot was counted under. The caller must later convert the slot
    /// with [`FleetState::bind`] or return it with
    /// [`FleetState::cancel_admission`], passing that same site back —
    /// exactly one of the two, exactly once, or the quota counters
    /// drift. Threading the site through (instead of re-reading the
    /// registry at bind/cancel time) is what keeps the ledger exact
    /// even if the worker is marked lost or GC'd mid-ask.
    pub fn admit(
        &mut self,
        worker_id: u64,
        study_key: &str,
        tenant: Option<&str>,
        now: f64,
        config: &FleetConfig,
    ) -> Result<String, ApiError> {
        let worker = self
            .registry
            .get(worker_id)
            .ok_or_else(|| ApiError::NotFound(format!("unknown worker {worker_id}")))?;
        if worker.state != WorkerState::Alive {
            return Err(ApiError::Conflict(format!(
                "worker {worker_id} is {}: re-register before asking",
                worker.state.as_str()
            )));
        }
        let site = worker.site.clone();
        self.sched.admit(&site, study_key, tenant, now, config)?;
        Ok(site)
    }

    /// Return an admission slot that never became a lease. `site` is
    /// the key [`FleetState::admit`] returned; the release is
    /// unconditional — the worker may have vanished from the registry
    /// meanwhile, but the counted slot must come back regardless.
    pub fn cancel_admission(&mut self, site: &str, study_key: &str, tenant: Option<&str>) {
        self.sched.release(site, study_key, tenant);
    }

    /// Convert an admission slot into a live lease (ask success path).
    /// The lease records the admission keys (`site` as returned by
    /// [`FleetState::admit`], plus the tenant) so the eventual release
    /// returns exactly the slot admission took.
    pub fn bind(
        &mut self,
        trial_id: u64,
        worker_id: u64,
        study_key: &str,
        site: &str,
        tenant: Option<&str>,
        now: f64,
    ) {
        // A requeued handout is in flight (popped, still marked
        // queued): the lease supersedes the mark.
        self.leases.finish_handout(trial_id);
        self.leases.bind(trial_id, worker_id, study_key, site, tenant, now);
        self.registry.attach(worker_id, trial_id);
        self.sched.note_handout(site);
        // The scheduler slot was already counted at admission.
    }

    /// Replay a `lease_bind` record: insert the lease (and pull the
    /// trial out of the requeue queue if it was waiting there) without
    /// admission bookkeeping — counts are rebuilt by
    /// [`FleetState::rebuild_counts`] at the end of recovery. `site`
    /// comes from the record; pre-policy records carried none, so fall
    /// back to the registry (the worker's `worker_register` replayed
    /// earlier in log order).
    pub fn apply_bind(
        &mut self,
        trial_id: u64,
        worker_id: u64,
        study_key: &str,
        site: &str,
        tenant: Option<&str>,
        at: f64,
    ) {
        let site = if site.is_empty() {
            self.registry.site_of(worker_id).unwrap_or("").to_string()
        } else {
            site.to_string()
        };
        self.leases.remove_from_queue(study_key, trial_id);
        self.leases.bind(trial_id, worker_id, study_key, &site, tenant, at);
        self.registry.attach(worker_id, trial_id);
        // Replay parity with the live bind: the handout counts toward
        // the site's health ledger. Binds covered by the fleet segment
        // never reach here — their handouts are already inside the
        // segment's persisted ledger.
        self.sched.note_handout(&site);
    }

    /// Release a trial's lease (tell/fail/prune or scrub). Returns the
    /// worker that held it, if any. The scheduler slot is returned under
    /// the lease's own admission keys — gating on the lease is what
    /// makes the release exactly-once even when a lease expiry races a
    /// deregister for the same trial.
    pub fn release(&mut self, trial_id: u64) -> Option<u64> {
        let info = self.leases.release(trial_id)?;
        self.registry.detach(info.worker, trial_id);
        self.sched
            .release(&info.site, &info.study_key, info.tenant.as_deref());
        // The trial is terminal: its requeue-budget entry (if any) is
        // dead bookkeeping — drop it or the table grows forever.
        self.leases.clear_requeues(trial_id);
        Some(info.worker)
    }

    /// Drop every trace of a trial: its lease if held, and its
    /// queue/budget entries if any. Used by every path that retires a
    /// trial from the fleet's point of view — terminal transitions
    /// (tell/fail/prune, including straggler tells on queued trials),
    /// requeue-budget exhaustion, reaping, and the lazy discard of
    /// terminal trials found in the requeue queue.
    pub fn finish_trial(&mut self, trial_id: u64, study_key: &str) {
        self.release(trial_id);
        self.leases.remove_from_queue(study_key, trial_id);
        self.leases.clear_requeues(trial_id);
    }

    /// Requeue a leased trial after its worker was lost. Returns `false`
    /// if the trial is no longer leased to `expected_worker` (a
    /// concurrent tell or a racing expiry already handled it), which is
    /// what makes requeueing exactly-once. Charges the loss to the
    /// site's health ledger (affinity input).
    pub fn requeue(&mut self, trial_id: u64, expected_worker: u64, now: f64) -> bool {
        let Some(info) = self.leases.get(trial_id) else { return false };
        if info.worker != expected_worker {
            return false;
        }
        let info = self.leases.release(trial_id).expect("lease checked above");
        self.registry.detach(info.worker, trial_id);
        self.sched
            .release(&info.site, &info.study_key, info.tenant.as_deref());
        self.sched.note_loss(&info.site);
        self.leases.push_back(&info.study_key, trial_id, now);
        true
    }

    /// Replay a `trial_requeue` record. Replayed queue entries read as
    /// waited-forever, so the affinity preference never defers them.
    /// The loss is charged to the releasing lease's site, mirroring the
    /// live [`FleetState::requeue`] path.
    pub fn apply_requeue(&mut self, trial_id: u64, study_key: &str) {
        if let Some(info) = self.leases.release(trial_id) {
            self.registry.detach(info.worker, trial_id);
            self.sched.note_loss(&info.site);
        }
        self.leases.push_back(study_key, trial_id, f64::NEG_INFINITY);
    }

    /// Workers whose trials must be recovered: alive workers past their
    /// deadline, plus lost/deregistered workers still holding leases
    /// (a crash can land between `worker_lost` and the per-trial
    /// requeue records).
    pub fn expired_workers(&self, now: f64) -> Vec<(u64, bool, Vec<u64>)> {
        self.registry
            .iter()
            .filter_map(|w| {
                let expired_alive = w.state == WorkerState::Alive && w.deadline < now;
                let orphaned = w.state != WorkerState::Alive && !w.leases.is_empty();
                if expired_alive || orphaned {
                    let mut trials: Vec<u64> = w.leases.iter().copied().collect();
                    trials.sort_unstable();
                    Some((w.id, expired_alive, trials))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Scrub after recovery: drop leases and queue entries whose trial
    /// is no longer running, then rebuild the scheduler counts from the
    /// surviving leases.
    pub fn scrub(&mut self, running: &HashSet<u64>) {
        for (tid, study_key) in self.leases.all_tracked() {
            if !running.contains(&tid) {
                if let Some(info) = self.leases.release(tid) {
                    self.registry.detach(info.worker, tid);
                }
                self.leases.remove_from_queue(&study_key, tid);
                self.leases.clear_requeues(tid);
            }
        }
        self.rebuild_counts();
    }

    /// Recompute the scheduler's usage counters from the lease table
    /// (recovery; counts are otherwise maintained incrementally). Every
    /// lease carries its admission keys, so site and tenant counters
    /// come back exactly as live admission counted them.
    pub fn rebuild_counts(&mut self) {
        self.sched.reset_usage();
        let entries: Vec<(String, String, Option<String>)> = self
            .leases
            .iter()
            .map(|(_, info)| (info.site.clone(), info.study_key.clone(), info.tenant.clone()))
            .collect();
        for (site, study_key, tenant) in entries {
            self.sched.count_existing(&site, &study_key, tenant.as_deref());
        }
    }

    /// Serialize the whole fleet for the compaction segment. The
    /// `sites` block is the persisted health ledger (`handed`/`lost`
    /// per site) — affinity continuity across restarts.
    pub fn snapshot_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("next_worker_id", self.registry.next_id())
            .set("workers", self.registry.to_json())
            .set("leases", self.leases.leases_json())
            .set("requeue", self.leases.queues_json())
            .set("requeue_count", self.leases.requeue_counts_json())
            .set("sites", self.sched.health_json());
        Value::Obj(o)
    }

    /// Load the fleet from a compaction segment (recovery, before the
    /// fleet events of the surviving logs replay on top).
    pub fn load_snapshot(&mut self, v: &Value) {
        self.registry.load_json(v.get("workers"), v.get("next_worker_id").as_u64().unwrap_or(1));
        self.leases.load_json(v.get("leases"), v.get("requeue"), v.get("requeue_count"));
        // Health ledger first: rebuild_counts resets usage but keeps
        // (and the replayed fleet tail then adds to) handed/lost.
        // Pre-ledger segments simply carry no "sites" block — the
        // ledger then restarts at zero, the old behavior.
        self.sched.load_health(v.get("sites"));
        // Pre-policy segments carried no per-lease site: backfill from
        // the registry so rebuilt counters land on the right site.
        let fixups: Vec<(u64, String)> = self
            .leases
            .iter()
            .filter(|(_, info)| info.site.is_empty())
            .map(|(tid, info)| {
                (*tid, self.registry.site_of(info.worker).unwrap_or("").to_string())
            })
            .collect();
        for (tid, site) in fixups {
            self.leases.set_site(tid, &site);
        }
        for (tid, info) in self.leases.iter() {
            self.registry.attach(info.worker, *tid);
        }
        self.rebuild_counts();
    }

    /// The `/api/stats` fleet block.
    pub fn stats_json(&self, config: &FleetConfig) -> Value {
        let mut o = Value::obj();
        o.set("workers_alive", self.registry.count(WorkerState::Alive))
            .set("workers_lost", self.registry.count(WorkerState::Lost))
            .set("workers_total", self.registry.len())
            .set("leases", self.leases.len())
            .set("requeue_depth", self.leases.queue_depth())
            .set("lease_timeout", config.lease_timeout)
            .set("site_quota", config.policy.site_quota)
            .set("study_quota", config.policy.study_quota)
            .set("policy", config.policy.to_json())
            .set("sites", self.sched.sites_json(&config.policy))
            .set("tenants", self.sched.tenants_json(&config.policy));
        Value::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_fleet(site_quota: u32, study_quota: u32) -> (Fleet, FleetConfig) {
        let config = FleetConfig {
            lease_timeout: Some(10.0),
            requeue_max: 2,
            policy: QuotaPolicy { site_quota, study_quota, ..Default::default() },
            ..Default::default()
        };
        (Fleet::new(config.clone()), config)
    }

    fn register(st: &mut FleetState, name: &str, site: &str, now: f64) -> u64 {
        let id = st.registry.next_id();
        st.registry.apply_register(id, name, site, "gpu", now, now + 10.0);
        id
    }

    #[test]
    fn admission_bind_release_roundtrip() {
        let (fleet, cfg) = make_fleet(2, 0);
        let mut st = fleet.lock();
        let w = register(&mut st, "n1", "cloud", 0.0);
        let site = st.admit(w, "s", None, 0.0, &cfg).unwrap();
        assert_eq!(site, "cloud", "admit returns the counted site");
        st.bind(1, w, "s", &site, None, 0.0);
        assert_eq!(st.leases.len(), 1);
        st.admit(w, "s", None, 0.0, &cfg).unwrap();
        st.bind(2, w, "s", &site, None, 0.0);
        // Site full.
        assert!(matches!(st.admit(w, "s", None, 0.0, &cfg), Err(ApiError::Quota(_))));
        assert_eq!(st.release(1), Some(w));
        st.admit(w, "s", None, 1.0, &cfg).unwrap();
        st.cancel_admission("cloud", "s", None);
        assert_eq!(st.leases.len(), 1);
    }

    #[test]
    fn tenant_slots_follow_the_lease() {
        let (fleet, cfg) = {
            let config = FleetConfig {
                lease_timeout: Some(10.0),
                policy: QuotaPolicy { tenant_quota: 1, ..Default::default() },
                ..Default::default()
            };
            (Fleet::new(config.clone()), config)
        };
        let mut st = fleet.lock();
        let w = register(&mut st, "n1", "cloud", 0.0);
        let site = st.admit(w, "s", Some("alice"), 0.0, &cfg).unwrap();
        st.bind(1, w, "s", &site, Some("alice"), 0.0);
        assert_eq!(st.sched.tenant_active("alice"), 1);
        let err = st.admit(w, "s", Some("alice"), 0.0, &cfg).unwrap_err();
        assert!(err.to_string().contains("tenant 'alice'"), "{err}");
        // Releasing via the lease returns alice's slot (the lease
        // remembered the tenant; nothing depends on the caller).
        assert_eq!(st.release(1), Some(w));
        assert_eq!(st.sched.tenant_active("alice"), 0);
        let site = st.admit(w, "s", Some("alice"), 1.0, &cfg).unwrap();
        st.cancel_admission(&site, "s", Some("alice"));
        assert_eq!(st.sched.tenant_active("alice"), 0);
    }

    #[test]
    fn unknown_or_lost_worker_rejected() {
        let (fleet, cfg) = make_fleet(0, 0);
        let mut st = fleet.lock();
        assert!(matches!(st.admit(99, "s", None, 0.0, &cfg), Err(ApiError::NotFound(_))));
        let w = register(&mut st, "n1", "cloud", 0.0);
        st.registry.mark_lost(w, 5.0);
        assert!(matches!(st.admit(w, "s", None, 5.0, &cfg), Err(ApiError::Conflict(_))));
    }

    #[test]
    fn expiry_collects_and_requeues_exactly_once() {
        let (fleet, cfg) = make_fleet(0, 0);
        let mut st = fleet.lock();
        let w = register(&mut st, "n1", "spot", 0.0);
        let site = st.admit(w, "s", None, 0.0, &cfg).unwrap();
        st.bind(7, w, "s", &site, None, 0.0);
        assert!(st.expired_workers(5.0).is_empty(), "deadline not passed");
        let expired = st.expired_workers(11.0);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, w);
        assert!(expired[0].1, "was alive");
        assert_eq!(expired[0].2, vec![7]);
        st.registry.mark_lost(w, 11.0);
        assert!(st.requeue(7, w, 11.0));
        assert!(!st.requeue(7, w, 11.0), "second requeue is a no-op");
        assert_eq!(st.leases.queue_depth(), 1);
        assert_eq!(st.leases.pop_front("s"), Some(7));
        assert_eq!(st.leases.pop_front("s"), None);
        // A lost worker with no leases left is not re-collected.
        assert!(st.expired_workers(20.0).is_empty());
    }

    #[test]
    fn snapshot_roundtrips() {
        let (fleet, cfg) = make_fleet(4, 0);
        let snap = {
            let mut st = fleet.lock();
            let w1 = register(&mut st, "n1", "cloud", 1.0);
            let w2 = register(&mut st, "n2", "spot", 2.0);
            let s1 = st.admit(w1, "a", Some("alice"), 2.0, &cfg).unwrap();
            st.bind(10, w1, "a", &s1, Some("alice"), 2.0);
            let s2 = st.admit(w2, "b", None, 2.0, &cfg).unwrap();
            st.bind(11, w2, "b", &s2, None, 2.0);
            st.registry.mark_lost(w2, 3.0);
            assert!(st.requeue(11, w2, 3.0));
            st.snapshot_json()
        };
        let (fleet2, _) = make_fleet(4, 0);
        let mut st = fleet2.lock();
        st.load_snapshot(&snap);
        assert_eq!(st.registry.len(), 2);
        assert_eq!(st.leases.len(), 1);
        assert_eq!(st.leases.queue_depth(), 1);
        assert_eq!(st.leases.pop_front("b"), Some(11));
        assert_eq!(st.registry.next_id(), 3);
        assert_eq!(st.registry.count(WorkerState::Lost), 1);
        // Tenant counters rebuilt from the lease's admission keys.
        assert_eq!(st.sched.tenant_active("alice"), 1);
        assert_eq!(st.sched.site_active("cloud"), 1);
        // The health ledger rode the segment: spot's loss record (one
        // handout, one preemption) survives, so affinity keeps
        // deferring to it after a restart.
        assert!(!st.sched.site_preferred("spot"));
        assert!(st.sched.site_preferred("cloud"));
    }

    #[test]
    fn scrub_drops_dead_trials_and_rebuilds_counts() {
        let (fleet, cfg) = make_fleet(8, 0);
        let mut st = fleet.lock();
        let w = register(&mut st, "n1", "cloud", 0.0);
        for tid in [1u64, 2, 3] {
            let site = st.admit(w, "s", Some("t"), 0.0, &cfg).unwrap();
            st.bind(tid, w, "s", &site, Some("t"), 0.0);
        }
        st.registry.mark_lost(w, 1.0);
        assert!(st.requeue(3, w, 1.0));
        // Only trial 1 is still running after "recovery".
        let running: HashSet<u64> = [1u64].into_iter().collect();
        st.scrub(&running);
        assert_eq!(st.leases.len(), 1);
        assert_eq!(st.leases.queue_depth(), 0, "queued terminal trial dropped");
        assert_eq!(st.sched.site_active("cloud"), 1);
        assert_eq!(st.sched.tenant_active("t"), 1);
    }
}

//! # hopaas-rs
//!
//! A production-grade Rust reproduction of **HOPAAS** — *Hyperparameter
//! Optimization as a Service on INFN Cloud* (Barbetti & Anderlini, 2023).
//!
//! HOPAAS coordinates distributed hyperparameter-optimization campaigns
//! across heterogeneous, opportunistic compute nodes through a minimal set
//! of REST APIs (`ask`, `tell`, `should_prune`, `version`). This crate
//! implements:
//!
//! * the **coordination service** (`coordinator`): study/trial management,
//!   Bayesian and evolutionary samplers, pruners, token auth, metrics, and
//!   the HTTP API surface of the paper's Table 1;
//! * every **substrate** the service needs, from scratch: an HTTP/1.1
//!   server and client (`http`), a JSON codec (`json`), a durable
//!   WAL+snapshot store standing in for PostgreSQL (`store`), dense linear
//!   algebra for the GP sampler (`linalg`), and a deterministic PRNG
//!   (`rng`);
//! * the **workload** of the paper's §4 campaign: a Lamarr-like
//!   conditional GAN whose training step is AOT-compiled from JAX+Pallas
//!   to HLO and executed from Rust via PJRT (`runtime`, `gan`);
//! * the **fleet subsystem** (`fleet`): a worker registry with
//!   heartbeat leases, deterministic requeue of preempted trials, and a
//!   site-aware scheduler enforcing per-site/per-study quotas with
//!   fair-share admission;
//! * the **client fleet** (`worker`): a Rust HOPAAS client wrapping the
//!   REST APIs plus a multi-site node simulator (speed, availability,
//!   preemption) reproducing the paper's INFN/CERN/CINECA setup;
//! * synthetic **benchmark objectives** (`objectives`) used by the sampler
//!   and pruner studies.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request path is pure Rust.

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod fleet;
pub mod gan;
pub mod http;
pub mod json;
pub mod linalg;
pub mod objectives;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod store;
pub mod sync;
pub mod worker;

pub mod testutil;

/// Version string reported by the `/api/version` endpoint.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Git hash baked in at build time (`HOPAAS_GIT_HASH=$(git rev-parse
/// --short HEAD) cargo build`); `None` on plain builds — rendered as
/// `"unknown"` in `hopaas_build_info` and `/api/stats`.
pub const GIT_HASH: Option<&str> = option_env!("HOPAAS_GIT_HASH");

//! `hopaas-lint`: repo-specific static analysis for concurrency
//! correctness.
//!
//! The coordinator is a dense web of locks — registry, shard CS1/CS2,
//! view builders, the WAL writer queue, the replication ring, fleet
//! ledgers — and a single lock-order inversion or a guard held across
//! an fsync silently caps throughput or deadlocks the fleet. This
//! module is the static half of the PR-10 concurrency tooling (the
//! dynamic half is `crate::testutil::sched`, the deterministic
//! interleaving checker):
//!
//! * a hand-rolled Rust [`lexer`] (no new crate deps, in the spirit of
//!   the repo's `json`/`http`);
//! * the [`rules`]: the canonical lock [`HIERARCHY`] and the four
//!   checks (`lock_order`, `guard_blocking`, `determinism`,
//!   `unwrap_boundary`);
//! * [`baseline`]s that must only shrink, plus
//!   `// lint:allow(rule): reason` inline suppressions.
//!
//! Run it with `cargo run --bin hopaas-lint` (see `src/bin/`); CI runs
//! `hopaas-lint --deny` in the `analysis` job.

pub mod baseline;
pub mod lexer;
pub mod rules;

pub use rules::{lint_source, lint_sources, Finding, EFFECTS, HIERARCHY, RULES};

use std::path::{Path, PathBuf};

/// Directories under the source root that the lint does not scan:
/// test scaffolding is exempt from production lock discipline.
const SKIP_DIRS: &[&str] = &["testutil"];

/// Recursively collect the `.rs` sources under `root` (sorted for
/// deterministic output), skipping [`SKIP_DIRS`].
pub fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut files: Vec<PathBuf> = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if path.is_dir() {
                if !SKIP_DIRS.contains(&name) {
                    stack.push(path);
                }
            } else if name.ends_with(".rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for path in files {
        let rel = path.strip_prefix(root).unwrap_or(&path);
        // Labels are stable `src/…` paths whatever the invocation cwd.
        let label = format!("src/{}", rel.display()).replace('\\', "/");
        out.push((label, std::fs::read_to_string(&path)?));
    }
    Ok(out)
}

/// Lint every production source under `root` (a `src/` directory).
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    Ok(lint_sources(&collect_sources(root)?))
}

/// Locate the crate's `src/` from a checkout-relative cwd: works from
/// the repo root (`rust/src`) and from `rust/` (`src`).
pub fn default_src_root() -> Option<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Some(p);
        }
    }
    None
}

/// The default baseline path next to a given source root
/// (`<root>/../lint-baseline.txt`, i.e. `rust/lint-baseline.txt`).
pub fn default_baseline_path(src_root: &Path) -> PathBuf {
    src_root.parent().unwrap_or(Path::new(".")).join("lint-baseline.txt")
}

//! A lightweight Rust lexer for `hopaas-lint`.
//!
//! In the spirit of the repo's hand-rolled `json`/`http` substrates:
//! just enough tokenization to reason about lock acquisitions, call
//! chains and suppression comments — identifiers, punctuation,
//! literals (contents discarded), lifetimes vs. char literals, and
//! comments (retained, because `// lint:allow(...)` lives there).
//! It is not a parser and does not need to be: the lint rules work on
//! token shapes (`.lock()`, `let g = …;`, brace depth), which this
//! lexer preserves exactly.

/// Token category. Literal payloads other than comments are discarded:
/// the rules only ever compare identifier text and punctuation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
    Comment,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens. Never fails: unexpected bytes become
/// single-character `Punct` tokens, and an unterminated literal simply
/// runs to end of file — good enough for a lint that only reads the
/// crate's own (compiling) sources and test fixtures.
pub fn lex(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Vec::with_capacity(n / 4);
    let mut i = 0usize;
    let mut line = 1u32;

    let collect = |chars: &[char], a: usize, b: usize| -> String { chars[a..b].iter().collect() };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n {
            if chars[i + 1] == '/' {
                let start = i;
                while i < n && chars[i] != '\n' {
                    i += 1;
                }
                out.push(Tok { kind: TokKind::Comment, text: collect(&chars, start, i), line });
                continue;
            }
            if chars[i + 1] == '*' {
                let start = i;
                let start_line = line;
                i += 2;
                let mut depth = 1usize;
                while i < n && depth > 0 {
                    if chars[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                out.push(Tok {
                    kind: TokKind::Comment,
                    text: collect(&chars, start, i),
                    line: start_line,
                });
                continue;
            }
        }
        // Raw strings: r"…", r#"…"#, br"…", br#"…"# (any hash count).
        if c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                j += 1;
                // Scan for `"` followed by `hashes` hash marks.
                'raw: while j < n {
                    if chars[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if chars[j] == '"' {
                        let mut k = j + 1;
                        let mut seen = 0usize;
                        while k < n && seen < hashes && chars[k] == '#' {
                            seen += 1;
                            k += 1;
                        }
                        if seen == hashes {
                            j = k;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                out.push(Tok { kind: TokKind::Str, text: String::new(), line });
                i = j;
                continue;
            }
            // Not a raw string — fall through to identifier lexing.
        }
        // Byte strings / byte chars: b"…", b'…'.
        if c == 'b' && i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '\'') {
            i += 1;
            // Handled by the string/char branches below on the next pass
            // of the quote character; emit nothing for the `b` prefix.
            let q = chars[i];
            if q == '"' {
                i += 1;
                while i < n && chars[i] != '"' {
                    if chars[i] == '\\' {
                        i += 1;
                    }
                    if i < n && chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
                i = (i + 1).min(n);
                out.push(Tok { kind: TokKind::Str, text: String::new(), line });
            } else {
                i += 1;
                if i < n && chars[i] == '\\' {
                    i += 1;
                }
                i += 1;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                out.push(Tok { kind: TokKind::Char, text: String::new(), line });
            }
            continue;
        }
        // Strings.
        if c == '"' {
            i += 1;
            while i < n && chars[i] != '"' {
                if chars[i] == '\\' {
                    i += 1;
                } else if chars[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            i = (i + 1).min(n);
            out.push(Tok { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        // Char literal vs. lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // '\n', '\'', '\u{…}' — scan to the closing quote.
                i += 2;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                out.push(Tok { kind: TokKind::Char, text: String::new(), line });
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                // 'x'
                i += 3;
                out.push(Tok { kind: TokKind::Char, text: String::new(), line });
                continue;
            }
            if i + 1 < n && is_ident_start(chars[i + 1]) {
                // 'a, 'static — a lifetime.
                let start = i;
                i += 1;
                while i < n && is_ident_cont(chars[i]) {
                    i += 1;
                }
                out.push(Tok {
                    kind: TokKind::Lifetime,
                    text: collect(&chars, start, i),
                    line,
                });
                continue;
            }
            // Bare quote (shouldn't happen in valid Rust) — punct.
            out.push(Tok { kind: TokKind::Punct, text: "'".into(), line });
            i += 1;
            continue;
        }
        // Numbers: digits plus alphanumeric continuation (`0x`, `1e9`,
        // suffixes) and `.` only when followed by a digit, so `0..n`
        // lexes as Num, Punct('.'), Punct('.'), Ident/Num.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = chars[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && i + 1 < n && chars[i + 1].is_ascii_digit() {
                    i += 1;
                } else {
                    break;
                }
            }
            out.push(Tok { kind: TokKind::Num, text: collect(&chars, start, i), line });
            continue;
        }
        // Identifiers / keywords.
        if is_ident_start(c) {
            let start = i;
            i += 1;
            while i < n && is_ident_cont(chars[i]) {
                i += 1;
            }
            out.push(Tok { kind: TokKind::Ident, text: collect(&chars, start, i), line });
            continue;
        }
        // Everything else: one punct per char (`::` is two Punct(':')).
        out.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn lexes_basic_shapes() {
        let toks = lex("let g = self.state.lock().unwrap();");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(
            texts,
            vec!["let", "g", "=", "self", ".", "state", ".", "lock", "(", ")", ".", "unwrap", "(", ")", ";"]
        );
    }

    #[test]
    fn distinguishes_lifetimes_from_chars() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn skips_strings_and_nested_comments() {
        let src = r##"
            let s = "lock() inside a string";
            let r = r#"raw "with" quotes and lock()"#;
            /* outer /* nested */ still comment */
            call();
        "##;
        assert_eq!(idents(src), vec!["let", "s", "let", "r", "call"]);
    }

    #[test]
    fn keeps_comments_with_lines() {
        let src = "x();\n// lint:allow(lock_order): because\ny();";
        let toks = lex(src);
        let c = toks.iter().find(|t| t.kind == TokKind::Comment).unwrap();
        assert_eq!(c.line, 2);
        assert!(c.text.contains("lint:allow(lock_order)"));
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let toks = lex("for i in 0..10 {}");
        let nums: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "10"]);
    }
}

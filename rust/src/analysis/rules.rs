//! The `hopaas-lint` rules: lock hierarchy, guard-across-blocking,
//! determinism, and unwrap-at-boundary checks over the lexed token
//! stream.
//!
//! ## The canonical lock hierarchy
//!
//! [`HIERARCHY`] is the single declared source of truth for the
//! coordinator's lock order (ARCHITECTURE.md "Lock hierarchy &
//! concurrency analysis" renders the same table in prose). Locks may
//! only be acquired in **ascending level order** while other guards
//! are live. Receiver names (the identifier a
//! `.lock()`/`.read()`/`.write()` hangs off) map token shapes to
//! classes; a handful of well-known functions ([`EFFECTS`]) act as
//! named acquisitions (`lock_shard` returns the shard guard,
//! `persist`/`persist_many` block on the WAL writer roundtrip, the
//! view registry entry points take the per-study builder lock).
//! Receivers the table does not know are exempt from the hierarchy
//! rule (but still checked by the other rules).
//!
//! ## Rules
//!
//! * `lock_order` — an acquisition (direct, or via a function whose
//!   transitive effects include one) at a level ≤ any live guard's
//!   level. Effects propagate through the crate-local call graph, but
//!   only for functions whose name is defined exactly once in the
//!   scanned tree and is not a common std name — a hand-rolled lint
//!   must not confuse `Directory::push` with `Vec::push`.
//! * `guard_blocking` — any guard live across an fsync-class or
//!   blocking-socket call (`sync`, `sync_all`, `write_segment`,
//!   `connect`, `accept`, …). Deliberately *not* on the list: mpsc
//!   `recv` (the shard-lock-across-WAL-roundtrip is a core ordering
//!   guarantee of the engine) and condvar waits (they release the
//!   guard).
//! * `determinism` — `Instant::now` / `SystemTime::now` / `.now()` /
//!   `thread_rng` in replay- and replication-deterministic roots
//!   (`apply_repl_batch`, `apply_event`, recovery, sampler sources).
//!   Direct occurrences only, by design: the roots call broad shared
//!   helpers, and flagging transitively would drown the signal.
//! * `unwrap_boundary` — `.unwrap()`/`.expect()` directly on a lock
//!   result (use `lock_safe`/`read_safe`/`write_safe` from
//!   `crate::sync`) or on a network/parse boundary (`parse`,
//!   `from_utf8`, `recv`, `accept`, `connect`).
//!
//! Suppress a finding with `// lint:allow(<rule>): <reason>` on the
//! same line or the line above; the reason is part of the idiom.
//! `#[cfg(test)]` items and `src/testutil/` are not scanned.

use super::lexer::{lex, Tok, TokKind};
use std::collections::{BTreeMap, HashMap, HashSet};

/// One lock class in the canonical hierarchy.
pub struct LockClass {
    pub name: &'static str,
    /// Acquisition order: while holding a guard of level L, only locks
    /// with level strictly greater than L may be acquired.
    pub level: u32,
    /// Field/receiver identifiers that acquire this class via
    /// `.lock()` / `.read()` / `.write()` (and the `_safe` variants).
    pub receivers: &'static [&'static str],
    pub doc: &'static str,
}

/// The canonical lock hierarchy: registry → shard → view builder →
/// WAL queue → metrics/obs, with the auxiliary classes interleaved at
/// their acquisition points. Declared once, here.
pub const HIERARCHY: &[LockClass] = &[
    LockClass {
        name: "serial",
        level: 5,
        receivers: &["compact_lock", "follower_store"],
        doc: "whole-subsystem serialization points (compaction, follower apply/promote); \
              taken first, before any other engine lock",
    },
    LockClass {
        name: "registry",
        level: 10,
        receivers: &["directory"],
        doc: "the cross-study directory; readers copy out of it before locking a shard, \
              writers publish entries only after the owning shard guard is released",
    },
    LockClass {
        name: "bind_gate",
        level: 15,
        receivers: &["fleet_bind_gate"],
        doc: "the fleet segment-cut gate, held (shared) across ask critical sections",
    },
    LockClass {
        name: "shard",
        level: 20,
        receivers: &["state"],
        doc: "a shard's studies/trials/sampler state; the engine's central lock",
    },
    LockClass {
        name: "fleet",
        level: 25,
        receivers: &["fleet"],
        doc: "worker registry, leases and quota ledgers; acquired under a shard guard \
              on the bind path",
    },
    LockClass {
        name: "view_slots",
        level: 28,
        receivers: &["slots"],
        doc: "the view registry's slot map (study id → per-study slot)",
    },
    LockClass {
        name: "view_builder",
        level: 30,
        receivers: &["builder"],
        doc: "a study's materialized-view builder; serializes rebuild vs incremental update",
    },
    LockClass {
        name: "view_leaf",
        level: 35,
        receivers: &["view", "events"],
        doc: "published view snapshot and event log — leaves of the read path",
    },
    LockClass {
        name: "wal_queue",
        level: 40,
        receivers: &["queue"],
        doc: "the group-commit writer roundtrip; callers hold their shard lock across it \
              so per-shard WAL order equals per-shard mutation order",
    },
    LockClass {
        name: "wal_ledger",
        level: 42,
        receivers: &["ledger"],
        doc: "the WAL segment/manifest ledger, taken by the writer thread after fsync",
    },
    LockClass {
        name: "repl_ring",
        level: 44,
        receivers: &["inner"],
        doc: "the replication ring buffer (publish/ack/evict floor)",
    },
    LockClass {
        name: "router",
        level: 45,
        receivers: &["stripes"],
        doc: "trial-id → shard router stripes; tiny leaf critical sections",
    },
    LockClass {
        name: "obs",
        level: 50,
        receivers: &["site_leases", "sinks", "slow_ops", "spans", "series"],
        doc: "metrics and observability ledgers — always last",
    },
];

/// Functions that acquire a lock class by name: the named-acquisition
/// half of the hierarchy table. `held` marks functions returning a
/// guard (the acquisition outlives the call); the rest block inside
/// the call and release before returning.
pub struct EffectFn {
    pub name: &'static str,
    pub class: &'static str,
    pub held: bool,
}

pub const EFFECTS: &[EffectFn] = &[
    EffectFn { name: "lock_shard", class: "shard", held: true },
    EffectFn { name: "persist", class: "wal_queue", held: false },
    EffectFn { name: "persist_many", class: "wal_queue", held: false },
    EffectFn { name: "on_study_created", class: "view_builder", held: false },
    EffectFn { name: "on_trials_inserted", class: "view_builder", held: false },
    EffectFn { name: "on_trial_updated", class: "view_builder", held: false },
    EffectFn { name: "rebuild_from", class: "view_builder", held: false },
];

/// Calls a live guard must never span: fsync-class file operations and
/// blocking socket establishment/IO.
const BLOCKING_SINKS: &[&str] = &[
    "sync",
    "sync_all",
    "sync_data",
    "fsync",
    "write_segment",
    "connect",
    "accept",
    "read_exact",
    "write_all",
    "read_to_end",
];

/// Replay/replication-deterministic roots: function names whose bodies
/// must not read wall clocks or OS randomness. Checked directly (not
/// transitively) — see the module docs.
const DET_ROOTS: &[&str] = &[
    "apply_repl_batch",
    "apply_event",
    "apply_fleet_event",
    "apply_partition",
    "apply_partitions",
    "recover_study",
    "replay_trial_mut",
    "plan_replay",
    "study_from_json",
];

/// Path substrings whose every function is a deterministic root: the
/// samplers and the PRNG draw only from seeded streams.
const DET_ROOT_DIRS: &[&str] = &["coordinator/samplers", "rng.rs"];

/// Boundary calls whose `Result` must be handled, not unwrapped.
const UNWRAP_BOUNDARY_FNS: &[&str] =
    &["parse", "from_utf8", "from_str", "recv", "recv_timeout", "accept", "connect"];

/// Callee names excluded from call-graph effect propagation even when
/// uniquely defined in the tree: common std names a method call could
/// just as well resolve to.
const PROPAGATION_DENYLIST: &[&str] = &[
    "new", "clone", "drop", "default", "len", "is_empty", "push", "pop", "insert", "remove",
    "get", "get_mut", "take", "set", "send", "recv", "write", "read", "lock", "flush", "sync",
    "next", "iter", "collect", "contains", "clear", "append", "join", "spawn", "wait",
    "notify_all", "notify_one", "as_str", "as_ref", "as_mut", "to_string", "from", "into",
    "cmp", "eq", "hash", "fmt", "min", "max", "abs", "start", "open", "close", "run", "call",
    "build", "init", "reset", "update", "apply", "handle", "load", "store", "tick", "now",
];

const ACQUIRE_METHODS: &[&str] = &["lock", "read", "write", "lock_safe", "read_safe", "write_safe"];

/// Rule identifiers, as used in `lint:allow(<rule>)`.
pub const RULES: &[&str] = &["lock_order", "guard_blocking", "determinism", "unwrap_boundary"];

/// One lint finding. [`Finding::key`] is line-number-free so baselines
/// survive unrelated edits to the same file.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub func: String,
    pub line: u32,
    /// Stable discriminator within (rule, file, func) — e.g. the
    /// receiver pair for `lock_order` findings.
    pub detail: String,
    pub message: String,
}

impl Finding {
    pub fn key(&self) -> String {
        format!("{}|{}|{}|{}", self.rule, self.file, self.func, self.detail)
    }

    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {} (in `{}`)", self.file, self.line, self.rule, self.message, self.func)
    }
}

fn class_index(name: &str) -> Option<usize> {
    HIERARCHY.iter().position(|c| c.name == name)
}

fn class_of_receiver(recv: &str) -> Option<usize> {
    HIERARCHY.iter().position(|c| c.receivers.contains(&recv))
}

// ---------------------------------------------------------------------
// File parsing: functions, impl context, cfg(test) regions
// ---------------------------------------------------------------------

struct FnBody {
    /// `Type::name` inside an impl block, bare `name` otherwise.
    qual: String,
    name: String,
    /// Token range of the body, inclusive of the outer braces.
    body: (usize, usize),
}

struct ParsedFile {
    label: String,
    toks: Vec<Tok>,
    fns: Vec<FnBody>,
    /// Line → suppressed rules (from `lint:allow` comments).
    allows: HashMap<u32, HashSet<&'static str>>,
}

/// Index of the matching close brace for the open brace at `open`
/// (counting `{`/`}` puncts only).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if toks[i].is_punct('{') {
            depth += 1;
        } else if toks[i].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

fn parse_file(label: &str, src: &str) -> ParsedFile {
    let toks = lex(src);
    let mut allows: HashMap<u32, HashSet<&'static str>> = HashMap::new();
    for t in &toks {
        if t.kind != TokKind::Comment {
            continue;
        }
        if let Some(pos) = t.text.find("lint:allow(") {
            let rest = &t.text[pos + "lint:allow(".len()..];
            if let Some(end) = rest.find(')') {
                for rule in RULES {
                    if rest[..end].split(',').any(|r| r.trim() == *rule) {
                        allows.entry(t.line).or_default().insert(*rule);
                    }
                }
            }
        }
    }

    // Comment-free view of the token stream.
    let code: Vec<usize> =
        (0..toks.len()).filter(|&i| toks[i].kind != TokKind::Comment).collect();

    // `#[cfg(test)]` / `#[test]` skip regions: from the attribute
    // through the end of the following item's brace block.
    let mut skip = vec![false; toks.len()];
    let mut ci = 0usize;
    while ci < code.len() {
        let i = code[ci];
        let is_attr_start =
            toks[i].is_punct('#') && ci + 1 < code.len() && toks[code[ci + 1]].is_punct('[');
        if !is_attr_start {
            ci += 1;
            continue;
        }
        // Collect the attribute's words up to the matching `]`.
        let mut depth = 0usize;
        let mut cj = ci + 1;
        let mut words: Vec<&str> = Vec::new();
        while cj < code.len() {
            let t = &toks[code[cj]];
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.kind == TokKind::Ident {
                words.push(&t.text);
            }
            cj += 1;
        }
        let is_test_attr = words.first().is_some_and(|w| *w == "test")
            || (words.contains(&"cfg") && words.contains(&"test") && !words.contains(&"not"));
        if is_test_attr {
            // Skip through the following item's brace block.
            let mut ck = cj + 1;
            while ck < code.len() && !toks[code[ck]].is_punct('{') && !toks[code[ck]].is_punct(';')
            {
                ck += 1;
            }
            if ck < code.len() && toks[code[ck]].is_punct('{') {
                let close = match_brace(&toks, code[ck]);
                for s in skip.iter_mut().take(close + 1).skip(i) {
                    *s = true;
                }
                while ci < code.len() && code[ci] <= close {
                    ci += 1;
                }
                continue;
            }
        }
        ci = cj + 1;
    }

    // Function collection with impl context.
    let mut fns = Vec::new();
    let mut impl_stack: Vec<(usize, String)> = Vec::new();
    let mut ci = 0usize;
    while ci < code.len() {
        let i = code[ci];
        while impl_stack.last().is_some_and(|&(end, _)| i > end) {
            impl_stack.pop();
        }
        let t = &toks[i];
        if t.is_ident("impl") && !skip[i] {
            // Only treat as an impl *item* when a `{` follows before
            // any `;` — `impl Trait` in signatures falls through.
            let mut cj = ci + 1;
            let mut ty: Option<String> = None;
            let mut after_for: Option<String> = None;
            let mut saw_for = false;
            let mut is_item = false;
            while cj < code.len() {
                let u = &toks[code[cj]];
                if u.is_punct('{') {
                    is_item = true;
                    break;
                }
                if u.is_punct(';') || u.is_punct(')') || u.is_ident("fn") {
                    break;
                }
                if u.is_ident("for") {
                    saw_for = true;
                } else if u.is_ident("where") {
                    saw_for = false;
                } else if u.kind == TokKind::Ident {
                    if saw_for && after_for.is_none() {
                        after_for = Some(u.text.clone());
                    } else if ty.is_none() {
                        ty = Some(u.text.clone());
                    }
                }
                cj += 1;
            }
            if is_item {
                let open = code[cj];
                let close = match_brace(&toks, open);
                let name = after_for.or(ty).unwrap_or_else(|| "_".into());
                impl_stack.push((close, name));
                ci = cj + 1;
                continue;
            }
        }
        if t.is_ident("fn") && !skip[i] {
            if let Some(&nix) = code.get(ci + 1) {
                if toks[nix].kind == TokKind::Ident {
                    let name = toks[nix].text.clone();
                    // Body: first `{` before a top-level `;`.
                    let mut cj = ci + 2;
                    let mut open = None;
                    while cj < code.len() {
                        let u = &toks[code[cj]];
                        if u.is_punct('{') {
                            open = Some(code[cj]);
                            break;
                        }
                        if u.is_punct(';') {
                            break;
                        }
                        cj += 1;
                    }
                    if let Some(open) = open {
                        let close = match_brace(&toks, open);
                        let qual = match impl_stack.last() {
                            Some((_, tyname)) => format!("{tyname}::{name}"),
                            None => name.clone(),
                        };
                        fns.push(FnBody { qual, name, body: (open, close) });
                        // Skip the signature, then walk the body region
                        // normally so nothing inside is missed.
                        ci = code.iter().position(|&x| x == open).unwrap_or(ci + 2);
                        continue;
                    }
                }
            }
        }
        ci += 1;
    }

    ParsedFile { label: label.to_string(), toks, fns, allows }
}

// ---------------------------------------------------------------------
// Body walking: acquisitions, calls, guard liveness
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Guard {
    class: Option<usize>,
    receiver: String,
    name: Option<String>,
    line: u32,
    /// Scope depth the guard dies at (`None` = statement-transient).
    depth: Option<usize>,
}

/// A direct lock acquisition discovered in a body.
struct Acq {
    class: Option<usize>,
    receiver: String,
    method: String,
    /// Token index (raw) of the method ident.
    at: usize,
}

/// Skip back over one balanced bracket group ending at `i` (`]`, `)`
/// or `>`); returns the index before the matching opener.
fn skip_back_group(toks: &[Tok], i: usize) -> Option<usize> {
    let (close, open) = match toks[i].text.as_str() {
        "]" => (']', '['),
        ")" => (')', '('),
        ">" => ('>', '<'),
        _ => return None,
    };
    let mut depth = 0i64;
    let mut j = i;
    loop {
        if toks[j].is_punct(close) {
            depth += 1;
        } else if toks[j].is_punct(open) {
            depth -= 1;
            if depth == 0 {
                return j.checked_sub(1);
            }
        }
        j = j.checked_sub(1)?;
    }
}

/// The receiver identifier of a method call whose `.` is at raw index
/// `dot`: scan back over index/call groups to the nearest ident.
fn receiver_of(toks: &[Tok], dot: usize) -> String {
    let mut j = match dot.checked_sub(1) {
        Some(j) => j,
        None => return "?".into(),
    };
    loop {
        match toks[j].text.as_str() {
            "]" | ")" => match skip_back_group(toks, j) {
                Some(nj) => j = nj,
                None => return "?".into(),
            },
            _ => break,
        }
    }
    if toks[j].kind == TokKind::Ident {
        toks[j].text.clone()
    } else {
        "?".into()
    }
}

/// The callee identifier of the call whose argument list closes at raw
/// index `close` (a `)`). Handles turbofish (`parse::<u64>()`).
fn callee_of_close(toks: &[Tok], close: usize) -> Option<String> {
    let open = {
        let mut depth = 0i64;
        let mut j = close;
        loop {
            if toks[j].is_punct(')') {
                depth += 1;
            } else if toks[j].is_punct('(') {
                depth -= 1;
                if depth == 0 {
                    break j;
                }
            }
            j = j.checked_sub(1)?;
        }
    };
    let mut j = open.checked_sub(1)?;
    if toks[j].is_punct('>') {
        j = skip_back_group(toks, j)?;
        while toks[j].is_punct(':') {
            j = j.checked_sub(1)?;
        }
    }
    if toks[j].kind == TokKind::Ident {
        Some(toks[j].text.clone())
    } else {
        None
    }
}

/// Pass-1 summary of one function: what it acquires and calls.
struct FnSummary {
    acquires: Vec<Acq>,
    calls: Vec<String>,
}

/// The comment-free token indices of a body range.
fn body_code(toks: &[Tok], body: (usize, usize)) -> Vec<usize> {
    (body.0..=body.1.min(toks.len().saturating_sub(1)))
        .filter(|&i| toks[i].kind != TokKind::Comment)
        .collect()
}

fn summarize(toks: &[Tok], body: (usize, usize)) -> FnSummary {
    let mut acquires = Vec::new();
    let mut calls = Vec::new();
    let code = body_code(toks, body);
    for (ci, &i) in code.iter().enumerate() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        if ci + 1 >= code.len() || !toks[code[ci + 1]].is_punct('(') {
            continue;
        }
        let prev_dot = ci > 0 && toks[code[ci - 1]].is_punct('.');
        let zero_arg = ci + 2 < code.len() && toks[code[ci + 2]].is_punct(')');
        if prev_dot && zero_arg && ACQUIRE_METHODS.contains(&t.text.as_str()) {
            let recv = receiver_of(toks, code[ci - 1]);
            acquires.push(Acq {
                class: class_of_receiver(&recv),
                receiver: recv,
                method: t.text.clone(),
                at: i,
            });
            continue;
        }
        calls.push(t.text.clone());
    }
    FnSummary { acquires, calls }
}

// ---------------------------------------------------------------------
// The lint driver
// ---------------------------------------------------------------------

/// Lint a set of in-memory sources: `(label, source)` pairs. This is
/// the whole analysis — `lint_tree` in `mod.rs` just reads the files.
pub fn lint_sources(sources: &[(String, String)]) -> Vec<Finding> {
    let files: Vec<ParsedFile> = sources.iter().map(|(l, s)| parse_file(l, s)).collect();

    // Pass 1: per-function summaries + definition counts per name.
    let mut def_count: HashMap<String, usize> = HashMap::new();
    let mut summaries: Vec<Vec<FnSummary>> = Vec::with_capacity(files.len());
    for f in &files {
        let mut per_file = Vec::with_capacity(f.fns.len());
        for fb in &f.fns {
            *def_count.entry(fb.name.clone()).or_insert(0) += 1;
            per_file.push(summarize(&f.toks, fb.body));
        }
        summaries.push(per_file);
    }

    // Effects a call site may apply: declared EFFECTS always; crate
    // functions only when uniquely named and not std-ambiguous.
    let declared: HashSet<&str> = EFFECTS.iter().map(|e| e.name).collect();
    let propagatable = |name: &str| -> bool {
        def_count.get(name).copied().unwrap_or(0) == 1
            && !PROPAGATION_DENYLIST.contains(&name)
            && !ACQUIRE_METHODS.contains(&name)
    };
    let applicable = |name: &str| -> bool { declared.contains(name) || propagatable(name) };

    // Seed effects with declared classes and direct acquisitions, then
    // propagate to fixpoint through applicable callees.
    let mut effects: BTreeMap<String, HashSet<usize>> = BTreeMap::new();
    for e in EFFECTS {
        if let Some(ci) = class_index(e.class) {
            effects.entry(e.name.to_string()).or_default().insert(ci);
        }
    }
    for (fi, f) in files.iter().enumerate() {
        for (gi, fb) in f.fns.iter().enumerate() {
            let entry = effects.entry(fb.name.clone()).or_default();
            for a in &summaries[fi][gi].acquires {
                if let Some(c) = a.class {
                    entry.insert(c);
                }
            }
        }
    }
    loop {
        let mut changed = false;
        for (fi, f) in files.iter().enumerate() {
            for (gi, fb) in f.fns.iter().enumerate() {
                let mut add: HashSet<usize> = HashSet::new();
                for callee in &summaries[fi][gi].calls {
                    if applicable(callee) {
                        if let Some(es) = effects.get(callee.as_str()) {
                            add.extend(es.iter().copied());
                        }
                    }
                }
                if !add.is_empty() {
                    let entry = effects.entry(fb.name.clone()).or_default();
                    let before = entry.len();
                    entry.extend(add);
                    changed |= entry.len() != before;
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Pass 2: walk every body with guard tracking.
    let mut findings = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for (gi, fb) in f.fns.iter().enumerate() {
            check_body(f, fb, &summaries[fi][gi], &effects, &applicable, &mut findings);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

fn is_det_root(file: &str, fn_name: &str) -> bool {
    DET_ROOTS.contains(&fn_name) || DET_ROOT_DIRS.iter().any(|d| file.contains(d))
}

fn suppressed(f: &ParsedFile, rule: &'static str, line: u32) -> bool {
    [line, line.saturating_sub(1)]
        .iter()
        .any(|l| f.allows.get(l).is_some_and(|rs| rs.contains(rule)))
}

/// Comment-free index (within `code`) of the `)` closing the call
/// whose `(` sits at `code[open_ci]`.
fn close_of_call(toks: &[Tok], code: &[usize], open_ci: usize) -> usize {
    let mut depth = 0i64;
    let mut ck = open_ci;
    while ck < code.len() {
        let u = &toks[code[ck]];
        if u.is_punct('(') {
            depth += 1;
        } else if u.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return ck;
            }
        }
        ck += 1;
    }
    code.len().saturating_sub(1)
}

fn check_body(
    f: &ParsedFile,
    fb: &FnBody,
    summary: &FnSummary,
    effects: &BTreeMap<String, HashSet<usize>>,
    applicable: &dyn Fn(&str) -> bool,
    findings: &mut Vec<Finding>,
) {
    let toks = &f.toks;
    let det_root = is_det_root(&f.label, &fb.name);
    let code = body_code(toks, fb.body);
    let acq_at: HashMap<usize, &Acq> = summary.acquires.iter().map(|a| (a.at, a)).collect();

    let mut out: Vec<Finding> = Vec::new();
    let mut push = |rule: &'static str, line: u32, detail: String, message: String| {
        if !suppressed(f, rule, line) {
            out.push(Finding {
                rule,
                file: f.label.clone(),
                func: fb.qual.clone(),
                line,
                detail,
                message,
            });
        }
    };

    let mut depth = 0usize;
    let mut live: Vec<Guard> = Vec::new();
    let mut stmt_let: Option<String> = None;
    let mut stmt_start = true;

    // Check an acquisition of `cls` (named `what`) against live guards.
    let check_order =
        |live: &[Guard], push: &mut dyn FnMut(&'static str, u32, String, String), cls: usize, what: &str, line: u32| {
            for g in live {
                if let Some(gc) = g.class {
                    if HIERARCHY[gc].level >= HIERARCHY[cls].level {
                        push(
                            "lock_order",
                            line,
                            format!("{}<-{}", HIERARCHY[gc].name, what),
                            format!(
                                "acquires `{}` ({}, level {}) while holding `{}` ({}, level {}) — \
                                 violates the canonical lock order",
                                what,
                                HIERARCHY[cls].name,
                                HIERARCHY[cls].level,
                                g.receiver,
                                HIERARCHY[gc].name,
                                HIERARCHY[gc].level,
                            ),
                        );
                    }
                }
            }
        };

    let mut ci = 0usize;
    while ci < code.len() {
        let i = code[ci];
        let t = &toks[i];

        if t.is_punct('{') {
            depth += 1;
            stmt_start = true;
            stmt_let = None;
            ci += 1;
            continue;
        }
        if t.is_punct('}') {
            live.retain(|g| g.depth.is_some_and(|d| d < depth));
            depth = depth.saturating_sub(1);
            stmt_start = true;
            stmt_let = None;
            ci += 1;
            continue;
        }
        if t.is_punct(';') {
            live.retain(|g| g.depth.is_some());
            stmt_start = true;
            stmt_let = None;
            ci += 1;
            continue;
        }

        if stmt_start {
            if t.is_ident("let") {
                let mut cj = ci + 1;
                let mut name = None;
                while cj < code.len() {
                    let u = &toks[code[cj]];
                    if u.is_ident("mut") {
                        cj += 1;
                        continue;
                    }
                    if u.kind == TokKind::Ident {
                        name = Some(u.text.clone());
                    }
                    break;
                }
                stmt_let = Some(name.unwrap_or_else(|| "_".into()));
            }
            stmt_start = false;
        }

        // `drop(name)` ends a guard's liveness early.
        if t.is_ident("drop")
            && ci + 2 < code.len()
            && toks[code[ci + 1]].is_punct('(')
            && toks[code[ci + 2]].kind == TokKind::Ident
        {
            let victim = toks[code[ci + 2]].text.clone();
            live.retain(|g| g.name.as_deref() != Some(victim.as_str()));
        }

        // Direct lock acquisitions.
        if let Some(acq) = acq_at.get(&i) {
            if let Some(cls) = acq.class {
                check_order(&live, &mut push, cls, &acq.receiver, t.line);
            }
            // Consume trailing `.unwrap()/.expect(…)/.unwrap_or_else(…)`
            // — rule 4 on the first two, and they don't end the chain.
            let mut cj = ci + 2; // at the zero-arg call's `)`
            let mut poison_unwrap = false;
            loop {
                if cj + 2 < code.len()
                    && toks[code[cj + 1]].is_punct('.')
                    && toks[code[cj + 2]].kind == TokKind::Ident
                    && cj + 3 < code.len()
                    && toks[code[cj + 3]].is_punct('(')
                {
                    let m = toks[code[cj + 2]].text.as_str();
                    if m == "unwrap" || m == "expect" || m == "unwrap_or_else" {
                        if m != "unwrap_or_else" && !acq.method.ends_with("_safe") {
                            poison_unwrap = true;
                        }
                        cj = close_of_call(toks, &code, cj + 3);
                        continue;
                    }
                }
                break;
            }
            if poison_unwrap {
                push(
                    "unwrap_boundary",
                    t.line,
                    format!("{}.{}-unwrap", acq.receiver, acq.method),
                    format!(
                        "`{recv}.{m}().unwrap()` panics (and cascades) on poison — use \
                         `crate::sync`'s `{m}_safe()` instead",
                        recv = acq.receiver,
                        m = acq.method,
                    ),
                );
            }
            let chain_continues = cj + 1 < code.len() && toks[code[cj + 1]].is_punct('.');
            let held = !chain_continues && stmt_let.is_some();
            live.push(Guard {
                class: acq.class,
                receiver: acq.receiver.clone(),
                name: if held { stmt_let.clone() } else { None },
                line: t.line,
                depth: if held { Some(depth) } else { None },
            });
            ci += 1;
            continue;
        }

        // Calls.
        let next_is_call = ci + 1 < code.len() && toks[code[ci + 1]].is_punct('(');
        if t.kind == TokKind::Ident && next_is_call && !t.is_ident("drop") {
            let name = t.text.as_str();
            let prev_dot = ci > 0 && toks[code[ci - 1]].is_punct('.');

            // Rule 1 via declared/propagated effects.
            if applicable(name) {
                if let Some(classes) = effects.get(name) {
                    let mut cs: Vec<usize> = classes.iter().copied().collect();
                    cs.sort_unstable();
                    for cls in cs {
                        check_order(&live, &mut push, cls, &format!("{name}()"), t.line);
                    }
                }
                if let Some(e) = EFFECTS.iter().find(|e| e.name == name && e.held) {
                    let close = close_of_call(toks, &code, ci + 1);
                    let chain_continues =
                        close + 1 < code.len() && toks[code[close + 1]].is_punct('.');
                    let held = !chain_continues && stmt_let.is_some();
                    live.push(Guard {
                        class: class_index(e.class),
                        receiver: name.to_string(),
                        name: if held { stmt_let.clone() } else { None },
                        line: t.line,
                        depth: if held { Some(depth) } else { None },
                    });
                }
            }

            // Rule 2: blocking sink under any live guard.
            if prev_dot && BLOCKING_SINKS.contains(&name) {
                if let Some(g) = live.iter().find(|g| g.depth.is_some()).or_else(|| live.first())
                {
                    push(
                        "guard_blocking",
                        t.line,
                        format!("{}-across-{}", g.receiver, name),
                        format!(
                            "guard `{}` (acquired line {}) is live across blocking call \
                             `.{}()` — release it first",
                            g.receiver, g.line, name,
                        ),
                    );
                }
            }

            // Rule 3: wall clock / OS randomness in deterministic roots.
            if det_root {
                let path_now = name == "now"
                    && !prev_dot
                    && ci >= 3
                    && toks[code[ci - 1]].is_punct(':')
                    && toks[code[ci - 2]].is_punct(':')
                    && matches!(toks[code[ci - 3]].text.as_str(), "Instant" | "SystemTime");
                let method_now = name == "now" && prev_dot;
                let rng = name == "thread_rng";
                if path_now || method_now || rng {
                    let src = if path_now {
                        format!("{}::now", toks[code[ci - 3]].text)
                    } else {
                        format!(".{name}()")
                    };
                    push(
                        "determinism",
                        t.line,
                        format!("clock-{src}"),
                        format!(
                            "`{src}` in deterministic path `{}` — replay and replication \
                             must not read wall clocks or OS randomness",
                            fb.name,
                        ),
                    );
                }
            }

            // Rule 4 (boundary form): `boundary_call(…).unwrap()`.
            if (name == "unwrap" || name == "expect") && prev_dot && ci >= 2 {
                let rp = code[ci - 2];
                if toks[rp].is_punct(')') {
                    if let Some(callee) = callee_of_close(toks, rp) {
                        if UNWRAP_BOUNDARY_FNS.contains(&callee.as_str()) {
                            push(
                                "unwrap_boundary",
                                t.line,
                                format!("{callee}-unwrap"),
                                format!(
                                    "`.{name}()` on the result of `{callee}(…)` — \
                                     network/parse boundaries must handle errors",
                                ),
                            );
                        }
                    }
                }
            }
        }
        ci += 1;
    }
    findings.append(&mut out);
}

/// Lint a single in-memory source (fixture tests and the self-tests).
pub fn lint_source(label: &str, src: &str) -> Vec<Finding> {
    lint_sources(&[(label.to_string(), src.to_string())])
}

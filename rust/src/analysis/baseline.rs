//! Lint baselines: a committed set of known findings that must only
//! shrink.
//!
//! The baseline file is one [`Finding::key`] per line (sorted, `#`
//! comments and blank lines ignored). Keys are line-number-free, so
//! unrelated edits to a file don't churn the baseline. `--deny` fails
//! on any finding not in the baseline AND on any baseline entry that
//! no longer fires (stale entries must be deleted — that is the
//! "only shrinks" guarantee).

use super::rules::Finding;
use std::collections::BTreeSet;

/// Parse baseline text into the set of suppressed keys.
pub fn parse(text: &str) -> BTreeSet<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Render findings as baseline text (sorted, deduplicated).
pub fn render(findings: &[Finding]) -> String {
    let keys: BTreeSet<String> = findings.iter().map(Finding::key).collect();
    let mut out = String::from(
        "# hopaas-lint baseline — pre-existing findings, grandfathered.\n\
         # This file must only shrink: fix a finding, then delete its line.\n\
         # Regenerate with `cargo run --bin hopaas-lint -- --write-baseline`.\n",
    );
    for k in keys {
        out.push_str(&k);
        out.push('\n');
    }
    out
}

/// The comparison `--deny` acts on.
pub struct Diff<'a> {
    /// Findings not covered by the baseline (fail).
    pub new: Vec<&'a Finding>,
    /// Baseline keys that no longer fire (fail: delete them).
    pub stale: Vec<String>,
    /// Count of findings the baseline covers (allowed).
    pub baselined: usize,
}

pub fn diff<'a>(findings: &'a [Finding], baseline: &BTreeSet<String>) -> Diff<'a> {
    let fired: BTreeSet<String> = findings.iter().map(Finding::key).collect();
    let new: Vec<&Finding> =
        findings.iter().filter(|f| !baseline.contains(&f.key())).collect();
    let stale: Vec<String> =
        baseline.iter().filter(|k| !fired.contains(*k)).cloned().collect();
    let baselined = findings.len() - new.len();
    Diff { new, stale, baselined }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, func: &str, detail: &str) -> Finding {
        Finding {
            rule,
            file: file.into(),
            func: func.into(),
            line: 1,
            detail: detail.into(),
            message: String::new(),
        }
    }

    #[test]
    fn roundtrip_and_diff() {
        let f1 = finding("lock_order", "src/a.rs", "A::f", "shard<-registry");
        let f2 = finding("unwrap_boundary", "src/b.rs", "g", "x.lock-unwrap");
        let text = render(&[f1.clone(), f2.clone()]);
        let base = parse(&text);
        assert_eq!(base.len(), 2);

        // Same findings → nothing new, nothing stale.
        let all = vec![f1.clone(), f2.clone()];
        let d = diff(&all, &base);
        assert!(d.new.is_empty());
        assert!(d.stale.is_empty());
        assert_eq!(d.baselined, 2);

        // One fixed → its key is stale; one new → reported new.
        let f3 = finding("determinism", "src/c.rs", "h", "clock-.now()");
        let some = vec![f1, f3];
        let d = diff(&some, &base);
        assert_eq!(d.new.len(), 1);
        assert_eq!(d.new[0].rule, "determinism");
        assert_eq!(d.stale.len(), 1);
        assert!(d.stale[0].contains("unwrap_boundary"));
        assert_eq!(d.baselined, 1);
    }

    #[test]
    fn parse_ignores_comments_and_blanks() {
        let base = parse("# header\n\nrule|f|fn|d\n  \n# tail\n");
        assert_eq!(base.len(), 1);
        assert!(base.contains("rule|f|fn|d"));
    }
}

//! Poison-safe locking helpers.
//!
//! `std` mutexes poison when a holder panics, and the idiomatic
//! `lock().unwrap()` turns one panicked worker thread into a cascade:
//! every later thread touching the same lock aborts too. For a serving
//! system that is exactly backwards — the data under our locks is
//! always left in a consistent state at panic boundaries (mutations
//! are applied only after their WAL append succeeds, and view/metric
//! updates are idempotent), so the right recovery is to take the lock
//! anyway and keep serving.
//!
//! `LockExt` provides `lock_safe`/`read_safe`/`write_safe`, which
//! recover the guard from a poisoned lock via
//! [`std::sync::PoisonError::into_inner`]. The `hopaas-lint` rule
//! `unwrap_boundary` flags any remaining `lock().unwrap()` so new code
//! uses these instead (see `src/analysis/`).

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Poison-recovering accessors for [`Mutex`].
pub trait MutexExt<T: ?Sized> {
    /// Like `lock().unwrap()`, but recovers the guard when the lock is
    /// poisoned instead of propagating the panic.
    fn lock_safe(&self) -> MutexGuard<'_, T>;
}

impl<T: ?Sized> MutexExt<T> for Mutex<T> {
    fn lock_safe(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Poison-recovering accessors for [`RwLock`].
pub trait RwLockExt<T: ?Sized> {
    /// Like `read().unwrap()`, but recovers the guard on poison.
    fn read_safe(&self) -> RwLockReadGuard<'_, T>;
    /// Like `write().unwrap()`, but recovers the guard on poison.
    fn write_safe(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T: ?Sized> RwLockExt<T> for RwLock<T> {
    fn read_safe(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn write_safe(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_safe_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*m.lock_safe(), 7);
    }

    #[test]
    fn rwlock_safe_recovers_from_poison() {
        let l = Arc::new(RwLock::new(3u64));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*l.read_safe(), 3);
        *l.write_safe() = 4;
        assert_eq!(*l.read_safe(), 4);
    }
}

"""L2 correctness: GAN model shapes, Adam math, training smoke, and the
Wasserstein objective vs a numpy reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(7)


def hp(lr_g=1e-3, lr_d=2e-3, beta1=0.5, beta2=0.9, leak=0.1):
    return tuple(jnp.float32(x) for x in (lr_g, lr_d, beta1, beta2, leak))


def batch(key, n=model.BATCH):
    cond, real = model.synthetic_batch(key, n)
    noise = jax.random.normal(jax.random.fold_in(key, 1), (n, model.LATENT_DIM))
    return cond, real, noise


class TestShapes:
    @settings(max_examples=6, deadline=None)
    @given(variant=st.sampled_from(model.VARIANTS))
    def test_state_spec_consistent(self, variant):
        w, d = variant
        spec = model.state_spec(w, d)
        state = model.init_state(KEY, w, d)
        assert len(state) == len(spec)
        for arr, shape in zip(state, spec):
            assert tuple(arr.shape) == tuple(shape)

    def test_generator_output_shape(self):
        w, d = 32, 2
        state = model.init_state(KEY, w, d)
        ng = model.n_gen_arrays(w, d)
        cond, _, noise = batch(KEY)
        out = model.generator(state[:ng], cond, noise, jnp.float32(0.1))
        assert out.shape == (model.BATCH, model.FEAT_DIM)

    def test_discriminator_output_shape(self):
        w, d = 32, 2
        state = model.init_state(KEY, w, d)
        n_params = len(model.param_shapes(w, d))
        ng = model.n_gen_arrays(w, d)
        cond, real, _ = batch(KEY)
        out = model.discriminator(state[ng:n_params], cond, real, jnp.float32(0.1))
        assert out.shape == (model.BATCH,)

    def test_train_step_preserves_layout(self):
        w, d = 32, 2
        state = model.init_state(KEY, w, d)
        cond, real, noise = batch(KEY)
        new_state, loss_d, loss_g = model.train_step(
            w, d, state, cond, real, noise, *hp()
        )
        assert len(new_state) == len(state)
        for a, b in zip(new_state, state):
            assert a.shape == b.shape
        assert float(new_state[-1]) == 1.0  # t incremented
        assert np.isfinite(float(loss_d)) and np.isfinite(float(loss_g))


class TestTraining:
    def test_losses_move_toward_equilibrium(self):
        # 60 steps of LSGAN on the synthetic target: D loss should drop
        # from its untrained value and stay finite; the eval metric should
        # improve vs the untrained generator.
        w, d = 32, 2
        state = model.init_state(KEY, w, d)
        ng = model.n_gen_arrays(w, d)
        step = jax.jit(
            lambda state, cond, real, noise: model.train_step(
                w, d, state, cond, real, noise, *hp()
            )
        )
        key = KEY
        cond_e, real_e, noise_e = batch(jax.random.PRNGKey(999), model.EVAL_BATCH)
        w1_before = float(
            model.eval_step(w, d, state[:ng], cond_e, real_e, noise_e, jnp.float32(0.1))
        )
        losses = []
        for i in range(60):
            key = jax.random.fold_in(key, i)
            cond, real, noise = batch(key)
            state, loss_d, loss_g = step(state, cond, real, noise)
            losses.append((float(loss_d), float(loss_g)))
        assert all(np.isfinite(l) for pair in losses for l in pair)
        w1_after = float(
            model.eval_step(w, d, state[:ng], cond_e, real_e, noise_e, jnp.float32(0.1))
        )
        assert w1_after < w1_before, f"{w1_after} !< {w1_before}"

    def test_determinism(self):
        w, d = 32, 2
        cond, real, noise = batch(KEY)
        s1 = model.init_state(KEY, w, d)
        s2 = model.init_state(KEY, w, d)
        n1, ld1, lg1 = model.train_step(w, d, s1, cond, real, noise, *hp())
        n2, ld2, lg2 = model.train_step(w, d, s2, cond, real, noise, *hp())
        assert float(ld1) == float(ld2) and float(lg1) == float(lg2)
        for a, b in zip(n1, n2):
            np.testing.assert_array_equal(a, b)

    def test_lr_zero_freezes_params(self):
        w, d = 32, 2
        state = model.init_state(KEY, w, d)
        cond, real, noise = batch(KEY)
        new_state, _, _ = model.train_step(
            w, d, state, cond, real, noise,
            jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.5),
            jnp.float32(0.9), jnp.float32(0.1),
        )
        n_params = len(model.param_shapes(w, d))
        for a, b in zip(new_state[:n_params], state[:n_params]):
            np.testing.assert_allclose(a, b, atol=1e-7)


class TestObjective:
    def test_wasserstein_matches_numpy(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=(512, 4)).astype(np.float32)
        b = rng.normal(loc=0.5, size=(512, 4)).astype(np.float32)
        got = float(model.wasserstein1_per_feature(jnp.array(a), jnp.array(b)))
        want = np.mean(np.abs(np.sort(a, axis=0) - np.sort(b, axis=0)))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_wasserstein_zero_on_identical(self):
        a = jnp.arange(64.0).reshape(16, 4)
        assert float(model.wasserstein1_per_feature(a, a)) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(shift=st.floats(0.1, 2.0))
    def test_wasserstein_detects_shift(self, shift):
        rng = np.random.default_rng(1)
        a = jnp.array(rng.normal(size=(256, 4)), jnp.float32)
        b = a + jnp.float32(shift)
        got = float(model.wasserstein1_per_feature(a, b))
        np.testing.assert_allclose(got, shift, rtol=0.05)


class TestSyntheticData:
    def test_conditions_in_unit_cube(self):
        cond, real = model.synthetic_batch(KEY, 1024)
        assert cond.shape == (1024, model.COND_DIM)
        assert real.shape == (1024, model.FEAT_DIM)
        assert bool(jnp.all((cond >= 0) & (cond <= 1)))
        assert bool(jnp.all(jnp.isfinite(real)))

    def test_response_is_condition_dependent(self):
        # Split on p: the mean of feature 0 must differ strongly (mu0 ~ 2p-1).
        cond, real = model.synthetic_batch(KEY, 4096)
        low = real[cond[:, 0] < 0.3, 0]
        high = real[cond[:, 0] > 0.7, 0]
        assert float(jnp.mean(high) - jnp.mean(low)) > 0.5

    def test_feature_correlation(self):
        # y3 is built from mu0/mu1 + shared noise: corr(y0, y3) > 0.3.
        _, real = model.synthetic_batch(KEY, 8192)
        r = np.corrcoef(np.asarray(real[:, 0]), np.asarray(real[:, 3]))[0, 1]
        assert r > 0.3, f"corr={r}"

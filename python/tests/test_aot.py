"""Compile-path tests: signatures, manifest consistency, HLO emission."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


class TestSignatures:
    def test_train_signature_counts(self):
        w, d = 32, 2
        sig = aot.train_signature(w, d)
        n_state = len(model.state_spec(w, d))
        assert len(sig) == n_state + 3 + 5
        assert sig[n_state] == (model.BATCH, model.COND_DIM)
        assert sig[-1] == ()

    def test_eval_signature_counts(self):
        w, d = 64, 3
        sig = aot.eval_signature(w, d)
        assert len(sig) == model.n_gen_arrays(w, d) + 3 + 1
        assert sig[-2] == (model.EVAL_BATCH, model.LATENT_DIM)

    def test_manifest_consistent_with_model(self):
        m = aot.build_manifest([(32, 2), (64, 2)])
        assert m["batch"] == model.BATCH
        v = m["variants"][0]
        assert v["n_state"] == len(model.state_spec(32, 2))
        assert v["n_gen_arrays"] == model.n_gen_arrays(32, 2)
        assert len(v["train_inputs"]) == v["n_state"] + 8
        # Shapes serializable & round-trip through json.
        again = json.loads(json.dumps(m))
        assert again == m


class TestLowering:
    @pytest.fixture(scope="class")
    def lowered(self):
        return aot.lower_variant(32, 2)

    def test_hlo_text_valid_header(self, lowered):
        train_hlo, eval_hlo = lowered
        assert train_hlo.startswith("HloModule")
        assert eval_hlo.startswith("HloModule")

    def test_no_mosaic_custom_calls(self, lowered):
        # interpret=True must keep the kernels as plain HLO; a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        train_hlo, eval_hlo = lowered
        assert "mosaic" not in train_hlo.lower()
        assert "mosaic" not in eval_hlo.lower()

    def test_parameter_count_matches_signature(self, lowered):
        train_hlo, _ = lowered
        n_expected = len(aot.train_signature(32, 2))
        # Count distinct parameter declarations in the entry computation.
        header = train_hlo.split("\n", 1)[0]
        assert header.count("f32[") >= n_expected

    def test_deterministic_emission(self):
        a, _ = aot.lower_variant(32, 2)
        b, _ = aot.lower_variant(32, 2)
        assert a == b


class TestFlatEntryPoints:
    def test_eval_flat_matches_eager(self):
        """The positional AOT entry point must reproduce the eager model —
        these are the numbers the Rust PJRT client executes from the HLO
        text (the text round-trip itself is covered by the Rust runtime
        tests against artifacts/)."""
        import numpy as np

        w, d = 32, 2
        key = jax.random.PRNGKey(3)
        state = model.init_state(key, w, d)
        ng = model.n_gen_arrays(w, d)
        cond, real = model.synthetic_batch(key, model.EVAL_BATCH)
        noise = jax.random.normal(key, (model.EVAL_BATCH, model.LATENT_DIM))
        leak = jnp.float32(0.2)

        expected = float(model.eval_step(w, d, state[:ng], cond, real, noise, leak))
        flat = jax.jit(model.eval_step_flat(w, d))
        (got,) = flat(*state[:ng], cond, real, noise, leak)
        assert abs(float(got) - expected) < 1e-5 * max(1.0, abs(expected))
        assert np.isfinite(float(got))

    def test_train_flat_matches_train_step(self):
        w, d = 32, 2
        key = jax.random.PRNGKey(5)
        state = model.init_state(key, w, d)
        cond, real = model.synthetic_batch(key, model.BATCH)
        noise = jax.random.normal(key, (model.BATCH, model.LATENT_DIM))
        hps = tuple(jnp.float32(x) for x in (1e-3, 1e-3, 0.5, 0.9, 0.1))

        new_state, loss_d, loss_g = model.train_step(
            w, d, state, cond, real, noise, *hps
        )
        flat_out = jax.jit(model.train_step_flat(w, d))(*state, cond, real, noise, *hps)
        n_state = len(model.state_spec(w, d))
        assert len(flat_out) == n_state + 2
        import numpy as np

        for a, b in zip(flat_out[:n_state], new_state):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(flat_out[-2]), float(loss_d), rtol=1e-6)
        np.testing.assert_allclose(float(flat_out[-1]), float(loss_g), rtol=1e-6)

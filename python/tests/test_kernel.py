"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The CORE correctness signal of the compile path — hypothesis sweeps
shapes and slopes, asserting allclose against ref.py for forward and
custom-VJP gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import fused_dense, matmul, _block, TILE
from compile.kernels.ref import ref_fused_dense, ref_matmul

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# Dimensions exercised by the GAN variants (batch, widths, feature dims).
DIMS = st.sampled_from([1, 2, 3, 4, 7, 8, 11, 16, 32, 64, 128, 256])


class TestBlockChoice:
    def test_small_dims_get_full_block(self):
        for d in (1, 3, 11, 127, 128):
            assert _block(d) == min(d, TILE) or d <= TILE

    def test_large_dims_divide(self):
        for d in (256, 384, 512, 1024):
            b = _block(d)
            assert d % b == 0 and b <= TILE


class TestMatmul:
    @settings(max_examples=30, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, seed):
        a = rand(seed, (m, k))
        b = rand(seed + 1, (k, n))
        np.testing.assert_allclose(matmul(a, b), ref_matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_tiled_path_multiple_k_blocks(self):
        # k=256 -> 2 grid steps over K: exercises the accumulate-in-place.
        a = rand(0, (256, 256))
        b = rand(1, (256, 128))
        np.testing.assert_allclose(matmul(a, b), a @ b, rtol=1e-4, atol=1e-4)

    def test_identity(self):
        a = rand(2, (32, 32))
        eye = jnp.eye(32)
        np.testing.assert_allclose(matmul(a, eye), a, rtol=1e-6, atol=1e-6)


class TestFusedDense:
    @settings(max_examples=30, deadline=None)
    @given(
        m=DIMS,
        k=DIMS,
        n=DIMS,
        leak=st.floats(0.01, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, k, n, leak, seed):
        x = rand(seed, (m, k))
        w = rand(seed + 1, (k, n))
        b = rand(seed + 2, (n,))
        got = fused_dense(x, w, b, jnp.float32(leak))
        want = ref_fused_dense(x, w, b, jnp.float32(leak))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_leak_one_is_affine(self):
        x = rand(0, (64, 16))
        w = rand(1, (16, 8))
        b = rand(2, (8,))
        got = fused_dense(x, w, b, jnp.float32(1.0))
        np.testing.assert_allclose(got, x @ w + b[None, :], rtol=1e-5, atol=1e-5)

    def test_negative_side_scaled(self):
        x = -jnp.ones((4, 4), jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros(4, jnp.float32)
        got = fused_dense(x, w, b, jnp.float32(0.25))
        np.testing.assert_allclose(got, -0.25 * jnp.ones((4, 4)), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([4, 16, 64]),
        k=st.sampled_from([8, 32]),
        n=st.sampled_from([8, 32]),
        leak=st.floats(0.05, 0.9),
        seed=st.integers(0, 2**12),
    )
    def test_gradients_match_ref(self, m, k, n, leak, seed):
        x = rand(seed, (m, k))
        w = rand(seed + 1, (k, n))
        b = rand(seed + 2, (n,))
        leak = jnp.float32(leak)

        def loss(x, w, b, leak):
            return jnp.sum(jnp.tanh(fused_dense(x, w, b, leak)))

        def loss_ref(x, w, b, leak):
            return jnp.sum(jnp.tanh(ref_fused_dense(x, w, b, leak)))

        g = jax.grad(loss, argnums=(0, 1, 2, 3))(x, w, b, leak)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, w, b, leak)
        for a, r in zip(g, gr):
            np.testing.assert_allclose(a, r, rtol=2e-3, atol=2e-3)

    def test_grad_under_jit(self):
        x = rand(0, (32, 16))
        w = rand(1, (16, 32))
        b = rand(2, (32,))

        @jax.jit
        def f(w):
            return jnp.mean(fused_dense(x, w, b, jnp.float32(0.2)) ** 2)

        g = jax.grad(f)(w)
        assert g.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(g)))

    def test_dtype_bf16_close(self):
        x = rand(3, (64, 32)).astype(jnp.bfloat16)
        w = rand(4, (32, 16)).astype(jnp.bfloat16)
        b = rand(5, (16,)).astype(jnp.bfloat16)
        got = fused_dense(x, w, b, jnp.float32(0.2)).astype(jnp.float32)
        want = ref_fused_dense(
            x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32), 0.2
        )
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


class TestLoweringContainsKernelStructure:
    def test_fused_dense_lowers_inside_jit(self):
        # The kernel must lower into plain HLO (interpret mode) so the CPU
        # PJRT client can run it — no custom-call allowed.
        x = jnp.zeros((32, 16), jnp.float32)
        w = jnp.zeros((16, 8), jnp.float32)
        b = jnp.zeros((8,), jnp.float32)
        lowered = jax.jit(lambda x, w, b: fused_dense(x, w, b, jnp.float32(0.1))).lower(x, w, b)
        text = lowered.compiler_ir("stablehlo")
        assert "custom_call" not in str(text).lower() or "mosaic" not in str(text).lower()

"""Layer-2: the Lamarr-like conditional GAN, written in JAX on top of the
Pallas `fused_dense` kernel.

This is the workload of the paper's §4 campaign: a generative model of
high-level "detector response" features conditioned on kinematics, whose
hyperparameters HOPAAS optimizes. Everything is designed for AOT
execution from Rust:

* **All state is explicit.** The train step takes the flat list of
  parameter/optimizer arrays and returns the updated list in the same
  order, so the Rust runtime round-trips outputs to inputs without
  understanding the model.
* **Runtime hyperparameters are scalar inputs** (`lr_g`, `lr_d`,
  `beta1`, `beta2`, `leak`) so a single compiled artifact serves every
  continuous hyperparameter assignment.
* **Architecture hyperparameters are compile-time variants**: one
  artifact per (width, depth) — see `VARIANTS` and aot.py.
* **Randomness comes from outside**: latent noise and data batches are
  inputs produced by the Rust coordinator's RNG.

The adversarial objective is least-squares GAN (Mao et al. 2017) — the
stablest choice at this scale, with the same sensitivity to
hyperparameters that motivates the paper's campaigns.

Layout of the flat state list (see `state_spec`):
    [gen w0, gen b0, ..., disc w0, disc b0, ...,
     adam m (same order), adam v (same order), t]
"""

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import fused_dense

# ---------------------------------------------------------------------------
# Problem dimensions (fixed across variants; see DESIGN.md)
# ---------------------------------------------------------------------------

COND_DIM = 3      # kinematic conditions: (p, eta, nTracks) normalized
FEAT_DIM = 4      # generated detector-response features (PID-like)
LATENT_DIM = 8    # generator latent noise
BATCH = 256       # training batch
EVAL_BATCH = 512  # evaluation batch (Wasserstein estimate)

# Architecture variants compiled to separate artifacts: (width, depth).
VARIANTS = [(w, d) for w in (32, 64, 128) for d in (2, 3)]

ADAM_EPS = 1e-8


def layer_dims(width, depth):
    """Per-network layer dimension chains for a variant."""
    gen = [COND_DIM + LATENT_DIM] + [width] * depth + [FEAT_DIM]
    disc = [COND_DIM + FEAT_DIM] + [width] * depth + [1]
    return gen, disc


def param_shapes(width, depth):
    """Shapes of the trainable arrays, in flat-state order."""
    gen, disc = layer_dims(width, depth)
    shapes = []
    for dims in (gen, disc):
        for i in range(len(dims) - 1):
            shapes.append((dims[i], dims[i + 1]))  # w
            shapes.append((dims[i + 1],))          # b
    return shapes


def state_spec(width, depth):
    """Shapes of the *full* train-state list: params, adam m, adam v, t."""
    p = param_shapes(width, depth)
    return p + p + p + [()]


def n_gen_arrays(width, depth):
    """How many leading arrays of the param block belong to the generator."""
    gen, _ = layer_dims(width, depth)
    return 2 * (len(gen) - 1)


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------


def _mlp(params, x, leak):
    """Run an MLP given [(w, b), ...]; hidden layers use the fused Pallas
    block with the suggested LeakyReLU slope, the output layer is affine
    (leak = 1)."""
    n = len(params)
    for i, (w, b) in enumerate(params):
        slope = jnp.float32(1.0) if i == n - 1 else leak
        x = fused_dense(x, w, b, slope)
    return x


def _pair(flat):
    """Group a flat [w0, b0, w1, b1, ...] list into [(w, b), ...]."""
    return [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]


def generator(gen_flat, cond, noise, leak):
    """Generate FEAT_DIM response features for each condition row."""
    x = jnp.concatenate([cond, noise], axis=1)
    return _mlp(_pair(gen_flat), x, leak)


def discriminator(disc_flat, cond, feats, leak):
    """Score (cond, features) pairs; LSGAN targets 1 = real, 0 = fake."""
    x = jnp.concatenate([cond, feats], axis=1)
    return _mlp(_pair(disc_flat), x, leak)[:, 0]


# ---------------------------------------------------------------------------
# Training step (one D update + one G update, inlined Adam)
# ---------------------------------------------------------------------------


def _adam(params, grads, m, v, t, lr, beta1, beta2):
    new_m = [beta1 * mi + (1 - beta1) * g for mi, g in zip(m, grads)]
    new_v = [beta2 * vi + (1 - beta2) * g * g for vi, g in zip(v, grads)]
    mhat = [mi / (1 - beta1**t) for mi in new_m]
    vhat = [vi / (1 - beta2**t) for vi in new_v]
    new_p = [
        p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS)
        for p, mh, vh in zip(params, mhat, vhat)
    ]
    return new_p, new_m, new_v


def train_step(width, depth, state, cond, real, noise, lr_g, lr_d, beta1, beta2, leak):
    """One adversarial step. `state` is the flat list per `state_spec`.

    Returns `(new_state, loss_d, loss_g)`.
    """
    n_params = len(param_shapes(width, depth))
    ng = n_gen_arrays(width, depth)
    params = list(state[:n_params])
    m = list(state[n_params : 2 * n_params])
    v = list(state[2 * n_params : 3 * n_params])
    t = state[3 * n_params] + 1.0

    gen_flat, disc_flat = params[:ng], params[ng:]
    gen_m, disc_m = m[:ng], m[ng:]
    gen_v, disc_v = v[:ng], v[ng:]

    # --- discriminator update (LSGAN) ---------------------------------
    fake = jax.lax.stop_gradient(generator(gen_flat, cond, noise, leak))

    def d_loss_fn(disc_flat):
        d_real = discriminator(disc_flat, cond, real, leak)
        d_fake = discriminator(disc_flat, cond, fake, leak)
        return 0.5 * jnp.mean((d_real - 1.0) ** 2) + 0.5 * jnp.mean(d_fake**2)

    loss_d, d_grads = jax.value_and_grad(d_loss_fn)(disc_flat)
    disc_flat, disc_m, disc_v = _adam(disc_flat, d_grads, disc_m, disc_v, t, lr_d, beta1, beta2)

    # --- generator update against the updated discriminator -----------
    def g_loss_fn(gen_flat):
        fake = generator(gen_flat, cond, noise, leak)
        d_fake = discriminator(disc_flat, cond, fake, leak)
        return 0.5 * jnp.mean((d_fake - 1.0) ** 2)

    loss_g, g_grads = jax.value_and_grad(g_loss_fn)(gen_flat)
    gen_flat, gen_m, gen_v = _adam(gen_flat, g_grads, gen_m, gen_v, t, lr_g, beta1, beta2)

    new_state = (
        gen_flat + disc_flat + gen_m + disc_m + gen_v + disc_v + [t]
    )
    return new_state, loss_d, loss_g


def train_step_flat(width, depth):
    """The AOT entry point: a function of positional arrays only, returning
    one flat tuple `(state'..., loss_d, loss_g)`."""
    n_state = len(state_spec(width, depth))

    def fn(*args):
        state = list(args[:n_state])
        cond, real, noise, lr_g, lr_d, beta1, beta2, leak = args[n_state:]
        new_state, loss_d, loss_g = train_step(
            width, depth, state, cond, real, noise, lr_g, lr_d, beta1, beta2, leak
        )
        return tuple(new_state) + (loss_d, loss_g)

    return fn


# ---------------------------------------------------------------------------
# Evaluation: the objective HOPAAS minimizes
# ---------------------------------------------------------------------------


def wasserstein1_per_feature(gen_feats, real_feats):
    """Mean over features of the 1-D Wasserstein-1 distance between the
    generated and reference marginals (equal sample counts → mean abs
    difference of order statistics). Binning-free and robust — the same
    family of two-sample distances used to score the LHCb GAN
    parameterizations."""
    gen_sorted = jnp.sort(gen_feats, axis=0)
    real_sorted = jnp.sort(real_feats, axis=0)
    return jnp.mean(jnp.abs(gen_sorted - real_sorted))


def eval_step(width, depth, gen_flat, cond, real, noise, leak):
    """Objective for a hyperparameter assignment: W1 distance between a
    generated batch and a reference batch under the same conditions."""
    del width, depth
    fake = generator(list(gen_flat), cond, noise, leak)
    return wasserstein1_per_feature(fake, real)


def eval_step_flat(width, depth):
    """AOT entry point for evaluation: positional args, 1-tuple output."""
    ng = n_gen_arrays(width, depth)

    def fn(*args):
        gen_flat = list(args[:ng])
        cond, real, noise, leak = args[ng:]
        return (eval_step(width, depth, gen_flat, cond, real, noise, leak),)

    return fn


# ---------------------------------------------------------------------------
# Initialization + synthetic data (python-side tests; the Rust runtime
# re-implements both from the manifest)
# ---------------------------------------------------------------------------


def init_state(key, width, depth):
    """He-initialized params + zero Adam state, as the flat list."""
    shapes = param_shapes(width, depth)
    arrays = []
    for shape in shapes:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            std = (2.0 / shape[0]) ** 0.5
            arrays.append(std * jax.random.normal(sub, shape, jnp.float32))
        else:
            arrays.append(jnp.zeros(shape, jnp.float32))
    zeros = [jnp.zeros(s, jnp.float32) for s in shapes]
    return arrays + zeros + [z for z in zeros] + [jnp.float32(0.0)]


def synthetic_batch(key, batch):
    """The synthetic 'detector response' ground truth (see DESIGN.md §3):
    conditional, correlated, heteroscedastic — a miniature of the
    distributions Lamarr parameterizes. The Rust data generator
    (`gan/data.rs`) implements the same formulas."""
    k1, k2 = jax.random.split(key)
    cond = jax.random.uniform(k1, (batch, COND_DIM), jnp.float32)
    p, eta, ntr = cond[:, 0], cond[:, 1], cond[:, 2]
    eps = jax.random.normal(k2, (batch, FEAT_DIM), jnp.float32)
    s = 0.1 + 0.2 * ntr
    mu0 = 2.0 * p - 1.0 + 0.5 * jnp.sin(3.0 * eta)
    mu1 = p * eta
    mu2 = 0.5 * jnp.cos(3.0 * p) + 0.3 * ntr
    mu3 = 0.5 * mu0 + mu1
    y0 = mu0 + s * eps[:, 0]
    y1 = mu1 + s * eps[:, 1]
    y2 = mu2 + s * eps[:, 2]
    y3 = mu3 + s * eps[:, 3] + 0.3 * s * eps[:, 0]
    real = jnp.stack([y0, y1, y2, y3], axis=1)
    return cond, real

"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel must match
its `ref_*` counterpart to float tolerance under pytest/hypothesis sweeps
(python/tests/test_kernel.py). They are also used by the model tests as
a slow-but-simple reference implementation of the GAN layers.
"""

import jax.numpy as jnp


def ref_leaky_relu(z, leak):
    """LeakyReLU with slope `leak` for negative inputs."""
    return jnp.where(z >= 0, z, leak * z)


def ref_fused_dense(x, w, b, leak):
    """Reference for the fused dense block: leaky_relu(x @ w + b).

    `leak == 1.0` degenerates to a plain affine layer (used for output
    layers), matching the kernel's behaviour.
    """
    return ref_leaky_relu(x @ w + b[None, :], leak)


def ref_matmul(a, b):
    """Reference for the tiled matmul kernel."""
    return a @ b

"""Pallas kernels for the GAN hot path.

The compute hot-spot of the paper's §4 workload (GAN training for the
LHCb Lamarr parameterizations) is the dense layer: every forward and
backward pass of both generator and discriminator is dominated by
`leaky_relu(x @ W + b)` and its gradient matmuls.

Two kernels:

* :func:`fused_dense` — ``y = leaky_relu(x @ W + b)`` in a single tiled
  kernel: (bm, bk) x (bk, bn) partial products accumulate into the
  VMEM-resident output tile across the K grid dimension, and the bias +
  LeakyReLU epilogue runs on the last K step while the tile is still
  resident. This is the TPU re-think of the GPU fused GEMM+epilogue
  (DESIGN.md §Hardware-Adaptation): BlockSpec expresses the HBM<->VMEM
  schedule that a CUDA kernel would express with threadblock tiling, and
  the MXU gets ``jnp.dot(..., preferred_element_type=f32)`` on
  128-aligned tiles.

* :func:`matmul` — the same tiling without the epilogue, used by the
  custom VJP for the gradient matmuls (dx = dz @ W^T, dW = x^T @ dz).

Both run with ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness (and AOT) path, while the BlockSpec structure documents the
TPU schedule. The autodiff rule is supplied via ``jax.custom_vjp``
(pallas_call has no automatic transpose); the LeakyReLU mask is cheap
elementwise work left to XLA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. Dims smaller than a tile are handled by clamping
# the block to the full dim (small-variant networks).
TILE = 128


def _block(dim, tile=TILE):
    """Largest power-of-two-ish block <= tile that divides `dim` exactly
    (network dims here are powers of two or small feature counts)."""
    if dim <= tile:
        return dim
    b = tile
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def _fused_dense_kernel(x_ref, w_ref, b_ref, leak_ref, o_ref, *, nk):
    """Grid point (i, j, k): accumulate x[i,k] @ w[k,j] into the resident
    output tile; on the final k, add bias and apply LeakyReLU in place."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == nk - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...].astype(o_ref.dtype)
        leak = leak_ref[0, 0].astype(o_ref.dtype)
        o_ref[...] = jnp.where(z >= 0, z, leak * z)


def _fused_dense_impl(x, w, b, leak):
    m, kdim = x.shape
    _, n = w.shape
    bm, bn, bk = _block(m), _block(n), _block(kdim)
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)
    leak_arr = jnp.reshape(jnp.asarray(leak, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_fused_dense_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, jnp.reshape(b, (1, -1)), leak_arr)


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(a, b):
    """Tiled Pallas matmul (interpret mode). Dimensions must be divisible
    by their chosen block — true for every shape the GAN variants use."""
    m, kdim = a.shape
    _, n = b.shape
    bm, bn, bk = _block(m), _block(n), _block(kdim)
    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def fused_dense(x, w, b, leak):
    """``leaky_relu(x @ w + b)`` as one fused Pallas kernel.

    Args:
      x: ``(batch, in_features)``.
      w: ``(in_features, out_features)``.
      b: ``(out_features,)``.
      leak: scalar negative slope (a *traced* value — it is a runtime
        hyperparameter suggested by HOPAAS). ``leak = 1.0`` yields a
        plain affine layer, used for output layers.
    """
    return _fused_dense_impl(x, w, b, leak)


def _fused_dense_fwd(x, w, b, leak):
    y = _fused_dense_impl(x, w, b, leak)
    # sign(y) == sign(z) because leak > 0, so y itself carries the mask —
    # the pre-activation does not need to be materialized.
    return y, (x, w, leak, y)


def _fused_dense_bwd(res, dy):
    x, w, leak, y = res
    leak = jnp.asarray(leak, dy.dtype)
    mask = jnp.where(y >= 0, jnp.asarray(1.0, dy.dtype), leak)
    dz = dy * mask
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    # d/d(leak): contributions from the negative side, where z = y / leak.
    dleak = jnp.sum(jnp.where(y < 0, dy * y / leak, 0.0)).astype(jnp.float32)
    return dx, dw, db, dleak


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)

"""AOT compile path: lower the GAN train/eval steps to HLO text.

Emits, per (width, depth) variant:
  artifacts/gan_train_w{W}_d{D}.hlo.txt
  artifacts/gan_eval_w{W}_d{D}.hlo.txt
plus artifacts/manifest.json describing the exact positional signature
(array shapes in order), which the Rust runtime uses to build input
literals and to initialize parameters — no Python at run time.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowering goes stablehlo ->
XlaComputation with `return_tuple=True`; the Rust side unwraps with
`to_tuple()`.

Usage: python -m compile.aot --out ../artifacts [--variants 64x2,128x3]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def train_signature(width, depth):
    """Positional input shapes of the train artifact."""
    state = [s for s in model.state_spec(width, depth)]
    data = [
        (model.BATCH, model.COND_DIM),
        (model.BATCH, model.FEAT_DIM),
        (model.BATCH, model.LATENT_DIM),
    ]
    scalars = [()] * 5  # lr_g, lr_d, beta1, beta2, leak
    return state + data + scalars


def eval_signature(width, depth):
    """Positional input shapes of the eval artifact."""
    gen_shapes = model.param_shapes(width, depth)[: model.n_gen_arrays(width, depth)]
    data = [
        (model.EVAL_BATCH, model.COND_DIM),
        (model.EVAL_BATCH, model.FEAT_DIM),
        (model.EVAL_BATCH, model.LATENT_DIM),
    ]
    return gen_shapes + data + [()]  # + leak


def lower_variant(width, depth):
    """Lower both artifacts of one variant; returns (train_hlo, eval_hlo)."""
    train_args = [_spec(s) for s in train_signature(width, depth)]
    train_hlo = to_hlo_text(
        jax.jit(model.train_step_flat(width, depth)).lower(*train_args)
    )
    eval_args = [_spec(s) for s in eval_signature(width, depth)]
    eval_hlo = to_hlo_text(
        jax.jit(model.eval_step_flat(width, depth)).lower(*eval_args)
    )
    return train_hlo, eval_hlo


def build_manifest(variants):
    """Everything the Rust runtime needs to drive the artifacts."""
    out = {
        "cond_dim": model.COND_DIM,
        "feat_dim": model.FEAT_DIM,
        "latent_dim": model.LATENT_DIM,
        "batch": model.BATCH,
        "eval_batch": model.EVAL_BATCH,
        "variants": [],
    }
    for width, depth in variants:
        out["variants"].append(
            {
                "width": width,
                "depth": depth,
                "train_file": f"gan_train_w{width}_d{depth}.hlo.txt",
                "eval_file": f"gan_eval_w{width}_d{depth}.hlo.txt",
                "param_shapes": [list(s) for s in model.param_shapes(width, depth)],
                "n_gen_arrays": model.n_gen_arrays(width, depth),
                "n_state": len(model.state_spec(width, depth)),
                # Train outputs: state' (n_state) + loss_d + loss_g.
                "train_inputs": [list(s) for s in train_signature(width, depth)],
                "eval_inputs": [list(s) for s in eval_signature(width, depth)],
            }
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=None,
        help="comma-separated WxD list, e.g. 64x2,128x3 (default: all)",
    )
    args = ap.parse_args()

    variants = model.VARIANTS
    if args.variants:
        variants = [
            tuple(int(x) for x in v.split("x")) for v in args.variants.split(",")
        ]

    os.makedirs(args.out, exist_ok=True)
    for width, depth in variants:
        train_hlo, eval_hlo = lower_variant(width, depth)
        tpath = os.path.join(args.out, f"gan_train_w{width}_d{depth}.hlo.txt")
        epath = os.path.join(args.out, f"gan_eval_w{width}_d{depth}.hlo.txt")
        with open(tpath, "w") as f:
            f.write(train_hlo)
        with open(epath, "w") as f:
            f.write(eval_hlo)
        print(f"variant {width}x{depth}: {len(train_hlo)} + {len(eval_hlo)} chars")

    manifest = build_manifest(variants)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(variants)} variants to {args.out}")


if __name__ == "__main__":
    main()
